//! Tier-store bench: HBM capacity x tier config sweep (`BENCH_tiering.json`).
//!
//! One seeded MT-RAG hybrid workload through the sharded api::Server at
//! three per-shard HBM budgets (tight / medium / roomy), with eviction in
//! discard mode (no tier store) and demote mode (DRAM+SSD behind the
//! radix cache), each at 1/2/4/8 workers. Baseline RadixCache system
//! (no pilot) so both modes face identical LPM schedules — the
//! comparison isolates the eviction policy.
//!
//! Pinned invariants (the determinism/acceptance contract):
//!  * per-request reuse results — including the hot/warm/cold split —
//!    and the aggregate mean TTFT are bit-identical across worker counts;
//!  * with HBM constrained, demote mode reuses strictly more tokens and
//!    models strictly lower TTFT than discard mode, with identical
//!    hot-tier behaviour;
//!  * with roomy HBM the two modes are byte-identical (the store is inert).
//!
//! Sizes: `--cheap` (CI smoke) < default quick < CTXPILOT_FULL=1.

use std::sync::Arc;

use contextpilot::api::Server;
use contextpilot::cache::TierConfig;
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::{corpus_for, full_mode};
use contextpilot::util::cli::Args;
use contextpilot::util::json::Json;
use contextpilot::util::prop::reuse_fingerprint;
use contextpilot::util::table::{reset_result_file, Table};
use contextpilot::workload::{hybrid, Dataset};

const N_SHARDS: usize = 4;
const WORKER_SWEEP: [usize; 4] = [1, 2, 4, 8];

struct Cell {
    hbm: usize,
    demote: bool,
    workers: usize,
    wall_s: f64,
    req_per_s: f64,
    hit_ratio: f64,
    mean_ttft: f64,
    p99_ttft: f64,
    hot: u64,
    warm: u64,
    cold: u64,
    cached: u64,
    dram_resident: usize,
    ssd_resident: usize,
}

/// Deterministic result signature: per-request reuse fingerprint plus the
/// aggregate mean-TTFT bit pattern.
type Signature = (Vec<(u64, usize, usize, usize, usize, usize)>, u64);

fn run_once(
    w: &contextpilot::workload::Workload,
    corpus: &Arc<contextpilot::corpus::Corpus>,
    hbm: usize,
    tiers: Option<TierConfig>,
    workers: usize,
) -> (Signature, Cell) {
    let demote = tiers.is_some();
    let server = Server::builder(ModelSku::Qwen3_32B)
        .shards(N_SHARDS)
        .workers(workers)
        .capacity(hbm)
        .decode_tokens(16)
        .pilot(None) // baseline RadixCache: identical schedules both modes
        .tier_config(tiers)
        .corpus(corpus.clone())
        .build()
        .expect("bench tiering config is valid");
    let t0 = std::time::Instant::now();
    let served = server.serve_batch(&w.requests).expect("serve batch");
    let wall = t0.elapsed().as_secs_f64();
    let (mut m, per) = server.metrics().expect("metrics");
    let cell = Cell {
        hbm,
        demote,
        workers,
        wall_s: wall,
        req_per_s: served.len() as f64 / wall.max(1e-9),
        hit_ratio: m.hit_ratio(),
        mean_ttft: m.mean_ttft(),
        p99_ttft: m.p99_ttft(),
        hot: m.total_hot_hit_tokens,
        warm: m.total_warm_hit_tokens,
        cold: m.total_cold_hit_tokens,
        cached: m.total_cached_tokens,
        dram_resident: per.iter().map(|s| s.dram_resident_tokens).sum(),
        ssd_resident: per.iter().map(|s| s.ssd_resident_tokens).sum(),
    };
    ((reuse_fingerprint(&served), m.mean_ttft().to_bits()), cell)
}

fn main() {
    let args = Args::from_env();
    let cheap = args.flag("cheap");
    let quick = !full_mode();
    reset_result_file("tiering");
    let (sessions, turns) = if cheap {
        (24, 3)
    } else if quick {
        (64, 3)
    } else {
        (256, 6)
    };
    let w = hybrid(Dataset::MtRag, sessions, turns, 8, 0x71E21);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let t_start = std::time::Instant::now();

    // per-shard budgets: tight and medium force eviction under this
    // workload (~ sessions/shard x turns x ~1k tokens); roomy never evicts
    let hbm_sweep = [1_000usize, 4_000, 1 << 20];
    let tier_cfg = TierConfig::new(16_000, 64_000); // per shard

    let mut t = Table::new(
        &format!(
            "KV tiering — {} requests ({sessions} sessions x {turns} turns, MT-RAG) over {N_SHARDS} shards; dram={} ssd={} tok/shard, cost-aware admission",
            w.len(),
            tier_cfg.dram_tokens,
            tier_cfg.ssd_tokens
        ),
        &[
            "HBM/shard",
            "Evict mode",
            "Hit ratio",
            "Reuse tok (hot/warm/cold)",
            "Mean TTFT",
            "p99 TTFT",
            "Req/s (1..8w)",
        ],
    );

    let mut cells: Vec<Cell> = Vec::new();
    for &hbm in &hbm_sweep {
        let mut mode_sig: Vec<Signature> = Vec::new();
        let mut mode_cells: Vec<Cell> = Vec::new(); // the workers=1 cell per mode
        for demote in [false, true] {
            let tiers = demote.then(|| tier_cfg.clone());
            let mut sig: Option<Signature> = None;
            let mut rps = Vec::new();
            let mut first_cell: Option<Cell> = None;
            for workers in WORKER_SWEEP {
                let (s, cell) = run_once(&w, &corpus, hbm, tiers.clone(), workers);
                match &sig {
                    None => sig = Some(s),
                    Some(base) => assert_eq!(
                        *base, s,
                        "hbm={hbm} demote={demote} workers={workers} changed results"
                    ),
                }
                rps.push(cell.req_per_s);
                if first_cell.is_none() {
                    first_cell = Some(cell);
                } else {
                    cells.push(cell);
                }
            }
            let cell = first_cell.expect("worker sweep ran");
            t.row(vec![
                format!("{hbm}"),
                if demote { "demote" } else { "discard" }.to_string(),
                format!("{:.1}%", cell.hit_ratio * 100.0),
                format!("{} ({}/{}/{})", cell.cached, cell.hot, cell.warm, cell.cold),
                format!("{:.4}s", cell.mean_ttft),
                format!("{:.4}s", cell.p99_ttft),
                rps.iter()
                    .map(|r| format!("{r:.0}"))
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
            mode_cells.push(cell);
            mode_sig.push(sig.expect("sweep ran"));
        }
        // acceptance: mode comparison at this budget (workers=1 cells;
        // worker invariance was already asserted above)
        let (discard, demote) = (&mode_cells[0], &mode_cells[1]);
        assert_eq!(
            demote.hot, discard.cached,
            "hbm={hbm}: tiering changed hot-tier behaviour"
        );
        if hbm < (1 << 20) {
            assert!(
                demote.cached > discard.cached,
                "hbm={hbm}: demote reuse {} <= discard reuse {}",
                demote.cached,
                discard.cached
            );
            assert!(
                demote.mean_ttft < discard.mean_ttft,
                "hbm={hbm}: demote TTFT {} >= discard TTFT {}",
                demote.mean_ttft,
                discard.mean_ttft
            );
        } else {
            assert_eq!(
                mode_sig[0], mode_sig[1],
                "roomy HBM: the tier store must be inert"
            );
        }
        cells.extend(mode_cells);
    }
    t.emit("tiering");

    let json_rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("hbm_per_shard", Json::num(c.hbm as f64)),
                ("evict_mode", Json::str(if c.demote { "demote" } else { "discard" })),
                ("workers", Json::num(c.workers as f64)),
                ("wall_s", Json::num(c.wall_s)),
                ("req_per_s", Json::num(c.req_per_s)),
                ("hit_ratio", Json::num(c.hit_ratio)),
                ("mean_ttft_s", Json::num(c.mean_ttft)),
                ("p99_ttft_s", Json::num(c.p99_ttft)),
                ("hot_hit_tokens", Json::num(c.hot as f64)),
                ("warm_hit_tokens", Json::num(c.warm as f64)),
                ("cold_hit_tokens", Json::num(c.cold as f64)),
                ("cached_tokens", Json::num(c.cached as f64)),
                ("dram_resident_tokens", Json::num(c.dram_resident as f64)),
                ("ssd_resident_tokens", Json::num(c.ssd_resident as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("tiering")),
        ("dataset", Json::str("mtrag-hybrid")),
        ("requests", Json::num(w.len() as f64)),
        ("sessions", Json::num(sessions as f64)),
        ("turns", Json::num(turns as f64)),
        ("shards", Json::num(N_SHARDS as f64)),
        ("dram_tokens_per_shard", Json::num(tier_cfg.dram_tokens as f64)),
        ("ssd_tokens_per_shard", Json::num(tier_cfg.ssd_tokens as f64)),
        ("cheap", Json::Bool(cheap)),
        ("quick", Json::Bool(quick)),
        ("rows", Json::arr(json_rows)),
    ]);
    let json_path = "BENCH_tiering.json";
    std::fs::write(json_path, format!("{doc}\n")).expect("write BENCH_tiering.json");
    eprintln!(
        "bench_tiering done in {:.2}s (cheap={cheap} quick={quick}); wrote {json_path}",
        t_start.elapsed().as_secs_f64()
    );
}
