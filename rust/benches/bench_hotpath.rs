//! Hot-path micro-benchmarks (§Perf): the per-request coordinator
//! operations — index search, alignment, scheduling, dedup, radix cache
//! match/insert, prompt rendering. These are the numbers Table 8 rolls up
//! and the targets of the optimization pass in EXPERIMENTS.md §Perf.

use contextpilot::align::align_context;
use contextpilot::cache::RadixCache;
use contextpilot::corpus::{Corpus, CorpusConfig};
use contextpilot::dedup::{dedup_context, DedupConfig};
use contextpilot::engine::render::Renderer;
use contextpilot::experiments::table3c::synth_contexts;
use contextpilot::index::build::build_clustered;
use contextpilot::index::DEFAULT_ALPHA;
use contextpilot::pilot::{ContextPilot, PilotConfig};
use contextpilot::schedule::schedule_by_paths;
use contextpilot::tokenizer::Tokenizer;
use contextpilot::types::*;
use contextpilot::util::bench::{black_box, quick};
use contextpilot::util::prng::Rng;

fn main() {
    let base = synth_contexts(2_000, 15, 0xBE);
    let mut built = build_clustered(&base, DEFAULT_ALPHA);
    let queries = synth_contexts(512, 15, 0xBF);
    let corpus = Corpus::generate(
        &CorpusConfig {
            n_docs: 650,
            ..Default::default()
        },
        &Tokenizer::default(),
    );

    let mut qi = 0usize;
    let r = quick("index_search (2k contexts, k=15)", || {
        let (_, c) = &queries[qi % queries.len()];
        black_box(built.index.search(c));
        qi += 1;
    });
    println!("{}", r.report());

    let mut ai = 0usize;
    let r = quick("align_context (search+reorder+insert)", || {
        let (_, c) = &queries[ai % queries.len()];
        black_box(align_context(
            &mut built.index,
            c,
            RequestId(2_000_000 + ai as u64),
        ));
        ai += 1;
    });
    println!("{}", r.report());

    // placement probe: directory-backed known_blocks over a 2k-leaf index
    // — O(context blocks) per call, no leaf scan, no allocation
    let mut pi = 0usize;
    let r = quick("known_blocks probe (2k-leaf index, k=15)", || {
        let (_, c) = &queries[pi % queries.len()];
        black_box(built.index.known_blocks(c));
        pi += 1;
    });
    println!("{}", r.report());

    let dcfg = DedupConfig::default();
    let mut di = 0usize;
    let r = quick("dedup_context (block+CDC)", || {
        let (_, c) = &queries[di % queries.len()];
        black_box(dedup_context(
            &mut built.index,
            SessionId((di % 64) as u32),
            c,
            &corpus,
            &dcfg,
        ));
        di += 1;
    });
    println!("{}", r.report());

    let paths: Vec<Vec<usize>> = (0..256)
        .map(|i| {
            let mut rng = Rng::new(i);
            (0..rng.below(5)).map(|_| rng.below(6)).collect()
        })
        .collect();
    let r = quick("schedule_by_paths (256 reqs)", || {
        black_box(schedule_by_paths(&paths));
    });
    println!("{}", r.report());

    // radix cache ops on ~2k-token keys
    let mut cache: RadixCache<()> = RadixCache::new(1 << 22);
    let keys: Vec<Vec<u32>> = (0..128)
        .map(|i| {
            let mut rng = Rng::new(0xCAFE + i);
            let shared: Vec<u32> = (0..1024).map(|j| 16 + (j % 1000)).collect();
            let mut k = shared;
            k.extend((0..1024).map(|_| 16 + rng.below(2000) as u32));
            k
        })
        .collect();
    for (i, k) in keys.iter().enumerate() {
        cache.insert(k, RequestId(i as u64));
    }
    let mut ki = 0usize;
    let r = quick("radix match_prefix (2k-token key)", || {
        black_box(cache.match_prefix(&keys[ki % keys.len()]));
        ki += 1;
    });
    println!("{}", r.report());

    // full proxy batch path, clone-free (rewrite_batch borrows requests
    // and schedules over borrowed search paths — the hot path the serving
    // shards drive)
    let mut pilot = ContextPilot::new(PilotConfig::default());
    let mut bi = 0usize;
    let r = quick("pilot rewrite_batch (32 reqs, k=15)", || {
        let batch: Vec<Request> = (0..32)
            .map(|j| {
                let n = bi * 32 + j;
                let (_, c) = &queries[n % queries.len()];
                Request {
                    id: RequestId(3_000_000 + n as u64),
                    session: SessionId((j % 8) as u32),
                    turn: (bi % 4) as u32,
                    context: c.clone(),
                    query: QueryId(n as u64),
                }
            })
            .collect();
        black_box(pilot.rewrite_batch(&batch, &corpus));
        bi += 1;
    });
    println!("{}", r.report());

    let mut renderer = Renderer::new(Tokenizer::default());
    let req = Request {
        id: RequestId(1),
        session: SessionId(0),
        turn: 0,
        context: (0..15).map(BlockId).collect(),
        query: QueryId(1),
    };
    let prompt = Prompt::baseline(&req);
    let r = quick("render prompt (15 blocks)", || {
        black_box(renderer.render(&prompt, &corpus));
    });
    println!("{}", r.report());
}
