//! Open-loop load bench: offered QPS × shard count × worker count through
//! the continuous-batching scheduler (`BENCH_load.json`).
//!
//! One seeded multi-session workload per arrival process, submitted via
//! `Server::submit_at` at Poisson (and one diurnal) virtual arrival times
//! — no flush barrier anywhere: the per-shard scheduler loops admit each
//! request as its arrival time passes the determinism frontier, chunked
//! prefills interleave, and tickets resolve as their requests complete.
//! Each (arrival, qps, shards) cell runs at every worker count.
//!
//! Pinned invariants (the scheduler acceptance contract):
//!  * results are bit-identical across worker counts for every cell —
//!    per-request hit/miss AND the `queued_ttft` sojourn bit patterns;
//!  * goodput never exceeds offered QPS (served ≤ offered requests and
//!    the makespan covers the arrival span, so this holds exactly);
//!  * backpressure accounting is exact: the `backpressure_shed` counter
//!    equals the number of tickets that resolved to `Error::Overloaded`,
//!    unbounded cells shed/delay nothing, and the delay policy serves
//!    every request (`shed == 0`) while still counting delays.
//!
//! Sizes: `--cheap` (CI smoke) < default quick < CTXPILOT_FULL=1.

use std::sync::Arc;

use contextpilot::api::{Error, Server};
use contextpilot::corpus::Corpus;
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::{corpus_for, full_mode};
use contextpilot::serve::OverloadPolicy;
use contextpilot::util::cli::Args;
use contextpilot::util::histogram::Summary;
use contextpilot::util::json::Json;
use contextpilot::util::table::{reset_result_file, Table};
use contextpilot::workload::{open_loop, open_loop_diurnal, Dataset, TimedWorkload};

/// Per-request outcome signature: (id, prompt, cached, queued_ttft bits,
/// served?). Must be bit-identical across worker counts.
type Signature = Vec<(u64, usize, usize, u64, bool)>;

struct Cell {
    arrival: &'static str,
    policy: &'static str,
    qps_nominal: f64,
    qps_offered: f64,
    shards: usize,
    workers: usize,
    requests: usize,
    served: usize,
    shed: u64,
    delayed: u64,
    p50_ttft: f64,
    p99_ttft: f64,
    goodput: f64,
    makespan: f64,
    wall_s: f64,
}

struct Knobs {
    policy: OverloadPolicy,
    queue_bound: Option<usize>,
    deadline: Option<f64>,
}

impl Knobs {
    fn unbounded() -> Self {
        Knobs {
            policy: OverloadPolicy::Shed,
            queue_bound: None,
            deadline: None,
        }
    }

    fn bounded(&self) -> bool {
        self.queue_bound.is_some() || self.deadline.is_some()
    }
}

#[allow(clippy::too_many_arguments)]
fn run_once(
    tw: &TimedWorkload,
    corpus: &Arc<Corpus>,
    arrival: &'static str,
    qps_nominal: f64,
    shards: usize,
    workers: usize,
    knobs: &Knobs,
) -> (Signature, Cell) {
    let server = Server::builder(ModelSku::Qwen3_4B)
        .shards(shards)
        .workers(workers)
        .capacity(1 << 20) // roomy: the sweep isolates scheduling
        .decode_tokens(16)
        .prefill_chunk(2048)
        .queue_bound(knobs.queue_bound)
        .deadline(knobs.deadline)
        .overload(knobs.policy)
        .corpus(corpus.clone())
        .build()
        .expect("bench load config is valid");
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = tw
        .workload
        .requests
        .iter()
        .zip(&tw.arrivals)
        .map(|(req, &at)| server.submit_at(req.clone(), at).expect("submit arrival"))
        .collect();
    server.seal_arrivals().expect("seal arrivals");
    server.drain().expect("drain scheduler");
    let mut sig: Signature = Vec::with_capacity(tickets.len());
    let mut ttfts = Summary::new();
    let mut served = 0usize;
    let mut shed_tickets = 0u64;
    let mut completion_max = 0.0f64;
    for (ticket, (req, &at)) in tickets
        .into_iter()
        .zip(tw.workload.requests.iter().zip(&tw.arrivals))
    {
        match ticket.wait() {
            Ok(s) => {
                served += 1;
                ttfts.record(s.queued_ttft);
                completion_max = completion_max.max(at + s.queued_ttft);
                sig.push((
                    s.request.id.0,
                    s.prompt_tokens,
                    s.cached_tokens,
                    s.queued_ttft.to_bits(),
                    true,
                ));
            }
            Err(Error::Overloaded(id)) => {
                assert_eq!(id, req.id, "shed ticket reports the wrong request");
                shed_tickets += 1;
                sig.push((req.id.0, 0, 0, 0, false));
            }
            Err(e) => panic!("open-loop ticket failed: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let counter = |name: &str| {
        server
            .counters()
            .iter()
            .find(|(k, _)| *k == name)
            .map(|&(_, v)| v)
            .unwrap_or_else(|| panic!("counter {name} missing"))
    };
    let shed = counter("backpressure_shed");
    let delayed = counter("backpressure_delayed");
    // backpressure accounting is exact, not approximate
    assert_eq!(
        shed, shed_tickets,
        "backpressure_shed disagrees with Overloaded tickets"
    );
    assert_eq!(served as u64 + shed, tw.len() as u64, "tickets lost");
    if !knobs.bounded() {
        assert_eq!(shed, 0, "unbounded cell shed load");
        assert_eq!(delayed, 0, "unbounded cell delayed load");
    }
    if matches!(knobs.policy, OverloadPolicy::Delay) {
        assert_eq!(shed, 0, "delay policy must never shed");
        assert_eq!(served, tw.len(), "delay policy must serve everything");
    }
    // goodput vs offered: makespan covers the arrival span, so
    // served/makespan ≤ n/span holds exactly.
    let makespan = completion_max.max(tw.span());
    let qps_offered = tw.len() as f64 / tw.span().max(1e-9);
    let goodput = served as f64 / makespan.max(1e-9);
    assert!(
        goodput <= qps_offered + 1e-9,
        "goodput {goodput} exceeds offered {qps_offered}"
    );
    let cell = Cell {
        arrival,
        policy: knobs.policy.name(),
        qps_nominal,
        qps_offered,
        shards,
        workers,
        requests: tw.len(),
        served,
        shed,
        delayed,
        p50_ttft: ttfts.p50(),
        p99_ttft: ttfts.p99(),
        goodput,
        makespan,
        wall_s: wall,
    };
    (sig, cell)
}

fn main() {
    let args = Args::from_env();
    let cheap = args.flag("cheap");
    let quick = !full_mode();
    reset_result_file("load");
    let (sessions, k, qps_sweep, shard_sweep, worker_sweep): (
        usize,
        usize,
        Vec<f64>,
        Vec<usize>,
        Vec<usize>,
    ) = if cheap {
        (24, 6, vec![8.0, 64.0], vec![1, 2], vec![1, 2, 4])
    } else if quick {
        (48, 8, vec![4.0, 16.0, 64.0], vec![1, 4], vec![1, 2, 4, 8])
    } else {
        (160, 8, vec![2.0, 8.0, 32.0, 128.0], vec![1, 4, 8], vec![1, 2, 4, 8])
    };
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let t_start = std::time::Instant::now();

    let mut t = Table::new(
        &format!(
            "Open-loop load — {sessions} sessions x {k} blocks, MT-RAG corpus, \
             continuous batching (no flush barrier)"
        ),
        &[
            "Arrival",
            "QPS",
            "Shards",
            "Policy",
            "p50 TTFT",
            "p99 TTFT",
            "Goodput",
            "Shed/Delay",
            "Wall s (1..w)",
        ],
    );

    let mut cells: Vec<Cell> = Vec::new();
    let mut sweep = |tw: &TimedWorkload, arrival: &'static str, qps: f64, knobs: &Knobs| {
        for &shards in &shard_sweep {
            let mut sig: Option<Signature> = None;
            let mut walls = Vec::new();
            let mut first: Option<Cell> = None;
            for &workers in &worker_sweep {
                let (s, cell) = run_once(tw, &corpus, arrival, qps, shards, workers, knobs);
                match &sig {
                    None => sig = Some(s),
                    Some(base) => assert_eq!(
                        *base, s,
                        "{arrival} qps={qps} shards={shards} workers={workers} \
                         changed results"
                    ),
                }
                walls.push(cell.wall_s);
                if first.is_none() {
                    first = Some(cell);
                } else {
                    cells.push(cell);
                }
            }
            let cell = first.expect("worker sweep ran");
            t.row(vec![
                arrival.to_string(),
                format!("{:.1}", cell.qps_offered),
                format!("{shards}"),
                if knobs.bounded() {
                    cell.policy.to_string()
                } else {
                    "open".to_string()
                },
                format!("{:.4}s", cell.p50_ttft),
                format!("{:.4}s", cell.p99_ttft),
                format!("{:.1}/s", cell.goodput),
                format!("{}/{}", cell.shed, cell.delayed),
                walls
                    .iter()
                    .map(|w| format!("{w:.2}"))
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
            cells.push(cell);
        }
    };

    // Poisson sweep, unbounded: the base QPS ladder.
    for &qps in &qps_sweep {
        let tw = open_loop(Dataset::MtRag, sessions, k, qps, 0x10AD);
        sweep(&tw, "poisson", qps, &Knobs::unbounded());
    }
    // Diurnal swing at the middle rate.
    let mid = qps_sweep[qps_sweep.len() / 2];
    let diurnal = open_loop_diurnal(Dataset::MtRag, sessions, k, mid, 0.8, 4.0, 0x10AD);
    sweep(&diurnal, "diurnal", mid, &Knobs::unbounded());
    // Backpressure at the top rate: a tight queue bound under both
    // overload policies, and a deadline-based shed.
    let top = *qps_sweep.last().expect("qps sweep nonempty");
    let hot = open_loop(Dataset::MtRag, sessions, k, top, 0x10AD);
    sweep(
        &hot,
        "poisson",
        top,
        &Knobs {
            policy: OverloadPolicy::Shed,
            queue_bound: Some(1),
            deadline: None,
        },
    );
    sweep(
        &hot,
        "poisson",
        top,
        &Knobs {
            policy: OverloadPolicy::Delay,
            queue_bound: Some(1),
            deadline: None,
        },
    );
    sweep(
        &hot,
        "poisson",
        top,
        &Knobs {
            policy: OverloadPolicy::Shed,
            queue_bound: None,
            deadline: Some(0.05),
        },
    );
    t.emit("load");

    let json_rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("arrival", Json::str(c.arrival)),
                ("policy", Json::str(c.policy)),
                ("qps_nominal", Json::num(c.qps_nominal)),
                ("qps_offered", Json::num(c.qps_offered)),
                ("shards", Json::num(c.shards as f64)),
                ("workers", Json::num(c.workers as f64)),
                ("requests", Json::num(c.requests as f64)),
                ("served", Json::num(c.served as f64)),
                ("shed", Json::u64(c.shed)),
                ("delayed", Json::u64(c.delayed)),
                ("p50_ttft_s", Json::num(c.p50_ttft)),
                ("p99_ttft_s", Json::num(c.p99_ttft)),
                ("goodput_qps", Json::num(c.goodput)),
                ("makespan_s", Json::num(c.makespan)),
                ("wall_s", Json::num(c.wall_s)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("load")),
        ("dataset", Json::str("mtrag-multisession")),
        ("sessions", Json::num(sessions as f64)),
        ("k", Json::num(k as f64)),
        ("cheap", Json::Bool(cheap)),
        ("quick", Json::Bool(quick)),
        ("rows", Json::arr(json_rows)),
    ]);
    let json_path = "BENCH_load.json";
    std::fs::write(json_path, format!("{doc}\n")).expect("write BENCH_load.json");
    eprintln!(
        "bench_load done in {:.2}s (cheap={cheap} quick={quick}); wrote {json_path}",
        t_start.elapsed().as_secs_f64()
    );
}
