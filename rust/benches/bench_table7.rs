//! Bench target regenerating the paper's table7 (custom harness; see
//! DESIGN.md §3 experiment index). Quick sizes by default; paper-scale
//! with CTXPILOT_FULL=1.

use contextpilot::experiments::{table7, full_mode};
use contextpilot::util::table::reset_result_file;

fn main() {
    let quick = !full_mode();
    reset_result_file("table7");
    let t0 = std::time::Instant::now();
    for table in table7::run(quick) {
        table.emit("table7");
    }
    eprintln!("bench_table7 done in {:.2}s (quick={})", t0.elapsed().as_secs_f64(), quick);
}
