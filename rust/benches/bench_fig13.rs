//! Bench target regenerating the paper's fig13 (custom harness; see
//! DESIGN.md §3 experiment index). Quick sizes by default; paper-scale
//! with CTXPILOT_FULL=1.

use contextpilot::experiments::{fig13, full_mode};
use contextpilot::util::table::reset_result_file;

fn main() {
    let quick = !full_mode();
    reset_result_file("fig13");
    let t0 = std::time::Instant::now();
    for table in fig13::run(quick) {
        table.emit("fig13");
    }
    eprintln!("bench_fig13 done in {:.2}s (quick={})", t0.elapsed().as_secs_f64(), quick);
}
