//! Bench target regenerating the paper's appendix_f (custom harness; see
//! DESIGN.md §3 experiment index). Quick sizes by default; paper-scale
//! with CTXPILOT_FULL=1.

use contextpilot::experiments::{appendix_f, full_mode};
use contextpilot::util::table::reset_result_file;

fn main() {
    let quick = !full_mode();
    reset_result_file("appendix_f");
    let t0 = std::time::Instant::now();
    for table in appendix_f::run(quick) {
        table.emit("appendix_f");
    }
    eprintln!("bench_appendix_f done in {:.2}s (quick={})", t0.elapsed().as_secs_f64(), quick);
}
