//! Placement bench: placement policy × shard count × worker count on the
//! recurring-context workload (`BENCH_routing.json`).
//!
//! One seeded recurring-context workload (many sessions sharing a few RAG
//! corpora — the §7.2 / Table 6 routing scenario) through the sharded
//! `api::Server` under every placement policy, at several shard counts,
//! each at 1/2/4 workers. The ContextPilot proxy is ON for every cell so
//! the *only* independent variable per row is where sessions land.
//!
//! Pinned invariants (the placement acceptance contract):
//!  * per-request reuse results are bit-identical across worker counts
//!    for every (placement, shards) cell — placement happens at enqueue
//!    time, before workers run;
//!  * context-aware placement never loses to session hashing on cached
//!    tokens, and strictly beats it whenever there is more than one shard
//!    to get wrong;
//!  * at one shard every policy is byte-identical (placement is inert);
//!  * probe cost is O(request blocks), not O(alive index leaves):
//!    `placement_probe_ops` equals shards × Σ(distinct blocks of each
//!    probed first-turn request) exactly for context-aware placement (0
//!    for the lock-free policies), and `placement_probe_shard_locks` —
//!    shard mutexes taken from the probe path — is zero in every cell.
//!
//! Sizes: `--cheap` (CI smoke) < default quick < CTXPILOT_FULL=1.

use std::sync::Arc;

use contextpilot::api::Server;
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::{full_mode, turn_waves};
use contextpilot::serve::PlacementKind;
use contextpilot::util::cli::Args;
use contextpilot::util::json::Json;
use contextpilot::util::prop::reuse_fingerprint;
use contextpilot::util::table::{reset_result_file, Table};
use contextpilot::workload::{recurring, Dataset};

const PLACEMENTS: [PlacementKind; 3] = [
    PlacementKind::SessionHash,
    PlacementKind::RoundRobin,
    PlacementKind::ContextAware,
];
const SHARD_SWEEP: [usize; 3] = [1, 4, 8];
const WORKER_SWEEP: [usize; 3] = [1, 2, 4];

struct Cell {
    placement: PlacementKind,
    shards: usize,
    workers: usize,
    wall_s: f64,
    req_per_s: f64,
    hit_ratio: f64,
    cached: u64,
    affinity: u64,
    mean_ttft: f64,
    p99_ttft: f64,
    probe_ops: u64,
    probe_shard_locks: u64,
}

/// Deterministic result signature: per-request reuse fingerprint plus the
/// aggregate mean-TTFT bit pattern.
type Signature = (Vec<(u64, usize, usize, usize, usize, usize)>, u64);

fn run_once(
    w: &contextpilot::workload::Workload,
    corpus: &Arc<contextpilot::corpus::Corpus>,
    placement: PlacementKind,
    shards: usize,
    workers: usize,
) -> (Signature, Cell) {
    let server = Server::builder(ModelSku::Qwen3_32B)
        .shards(shards)
        .workers(workers)
        .capacity(1 << 20) // roomy: the sweep isolates placement
        .decode_tokens(16)
        .placement(placement)
        .corpus(corpus.clone())
        .build()
        .expect("bench routing config is valid");
    let t0 = std::time::Instant::now();
    let mut served = Vec::with_capacity(w.len());
    for (i, j) in turn_waves(&w.requests) {
        served.extend(server.serve_batch(&w.requests[i..j]).expect("serve wave"));
    }
    let wall = t0.elapsed().as_secs_f64();
    let counter = |name: &str| {
        server
            .counters()
            .iter()
            .find(|(k, _)| *k == name)
            .map(|&(_, v)| v)
            .unwrap_or_else(|| panic!("counter {name} missing"))
    };
    let probe_ops = counter("placement_probe_ops");
    let probe_shard_locks = counter("placement_probe_shard_locks");
    let (mut m, _) = server.metrics().expect("metrics");
    let cell = Cell {
        placement,
        shards,
        workers,
        wall_s: wall,
        req_per_s: served.len() as f64 / wall.max(1e-9),
        hit_ratio: m.hit_ratio(),
        cached: m.total_cached_tokens,
        affinity: m.total_affinity_hit_tokens,
        mean_ttft: m.mean_ttft(),
        p99_ttft: m.p99_ttft(),
        probe_ops,
        probe_shard_locks,
    };
    ((reuse_fingerprint(&served), m.mean_ttft().to_bits()), cell)
}

/// Ground-truth probe cost of one context-aware run: every first-turn
/// (unpinned) request is probed once, and a probe performs one block
/// lookup per *distinct* context block per shard — independent of how
/// many leaves the shard indexes hold. Pinned later turns never probe.
fn expected_probe_ops(w: &contextpilot::workload::Workload, shards: usize) -> u64 {
    let mut seen_sessions = std::collections::HashSet::new();
    let mut ops = 0u64;
    for r in &w.requests {
        if seen_sessions.insert(r.session) {
            let distinct: std::collections::HashSet<_> = r.context.iter().collect();
            ops += distinct.len() as u64;
        }
    }
    ops * shards as u64
}

fn main() {
    let args = Args::from_env();
    let cheap = args.flag("cheap");
    let quick = !full_mode();
    reset_result_file("routing");
    let (sessions, turns, groups, k) = if cheap {
        (24, 2, 6, 6)
    } else if quick {
        (64, 3, 8, 8)
    } else {
        (256, 4, 12, 10)
    };
    let w = recurring(Dataset::MtRag, sessions, turns, groups, k, 0x9047);
    let corpus = Arc::new(contextpilot::experiments::corpus_for(Dataset::MtRag));
    let t_start = std::time::Instant::now();

    let mut t = Table::new(
        &format!(
            "Reuse-aware placement — {} requests ({sessions} sessions x {turns} turns, \
             {groups} recurring context groups of {k} blocks, MT-RAG corpus)",
            w.len()
        ),
        &[
            "Shards",
            "Placement",
            "Hit ratio",
            "Cached tok",
            "Affinity tok",
            "Mean TTFT",
            "Probe ops",
            "Req/s (1..4w)",
        ],
    );

    let mut cells: Vec<Cell> = Vec::new();
    for &shards in &SHARD_SWEEP {
        let want_aware_ops = expected_probe_ops(&w, shards);
        let mut per_placement: Vec<(PlacementKind, Signature, Cell)> = Vec::new();
        for placement in PLACEMENTS {
            let mut sig: Option<Signature> = None;
            let mut rps = Vec::new();
            let mut first_cell: Option<Cell> = None;
            for &workers in &WORKER_SWEEP {
                let (s, cell) = run_once(&w, &corpus, placement, shards, workers);
                match &sig {
                    None => sig = Some(s),
                    Some(base) => assert_eq!(
                        *base, s,
                        "{placement} shards={shards} workers={workers} changed results"
                    ),
                }
                // probe-cost contract: O(request blocks), zero shard locks
                let want_ops = if placement == PlacementKind::ContextAware {
                    want_aware_ops
                } else {
                    0
                };
                assert_eq!(
                    cell.probe_ops, want_ops,
                    "{placement} shards={shards} workers={workers}: probe ops \
                     not shards x distinct first-turn request blocks"
                );
                assert_eq!(
                    cell.probe_shard_locks, 0,
                    "{placement} shards={shards} workers={workers}: probe path \
                     took a shard lock"
                );
                rps.push(cell.req_per_s);
                if first_cell.is_none() {
                    first_cell = Some(cell);
                } else {
                    cells.push(cell);
                }
            }
            let cell = first_cell.expect("worker sweep ran");
            t.row(vec![
                format!("{shards}"),
                placement.name().to_string(),
                format!("{:.1}%", cell.hit_ratio * 100.0),
                format!("{}", cell.cached),
                format!("{}", cell.affinity),
                format!("{:.4}s", cell.mean_ttft),
                format!("{}", cell.probe_ops),
                rps.iter()
                    .map(|r| format!("{r:.0}"))
                    .collect::<Vec<_>>()
                    .join("/"),
            ]);
            per_placement.push((placement, sig.expect("sweep ran"), cell));
        }
        // acceptance: the placement comparison at this shard count
        let cached_of = |kind: PlacementKind| {
            per_placement
                .iter()
                .find(|(p, _, _)| *p == kind)
                .map(|(_, _, c)| c.cached)
                .expect("cell ran")
        };
        let aware = cached_of(PlacementKind::ContextAware);
        let hashed = cached_of(PlacementKind::SessionHash);
        assert!(
            aware >= hashed,
            "shards={shards}: context-aware {aware} < session-hash {hashed} cached tokens"
        );
        if shards > 1 {
            assert!(
                aware > hashed,
                "shards={shards}: context-aware must strictly beat session-hash \
                 on the recurring workload ({aware} vs {hashed})"
            );
        } else {
            // one shard: placement cannot matter, byte-identical results
            let base = &per_placement[0].1;
            for (p, sig, _) in &per_placement[1..] {
                assert_eq!(sig, base, "shards=1: {p} diverged from {}", per_placement[0].0);
            }
        }
        for (_, _, c) in per_placement {
            cells.push(c);
        }
    }
    t.emit("routing");

    let json_rows: Vec<Json> = cells
        .iter()
        .map(|c| {
            Json::obj(vec![
                ("placement", Json::str(c.placement.name())),
                ("shards", Json::num(c.shards as f64)),
                ("workers", Json::num(c.workers as f64)),
                ("wall_s", Json::num(c.wall_s)),
                ("req_per_s", Json::num(c.req_per_s)),
                ("hit_ratio", Json::num(c.hit_ratio)),
                ("cached_tokens", Json::num(c.cached as f64)),
                ("affinity_hit_tokens", Json::num(c.affinity as f64)),
                ("mean_ttft_s", Json::num(c.mean_ttft)),
                ("p99_ttft_s", Json::num(c.p99_ttft)),
                ("probe_ops", Json::u64(c.probe_ops)),
                ("probe_shard_locks", Json::u64(c.probe_shard_locks)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("routing")),
        ("dataset", Json::str("mtrag-recurring")),
        ("requests", Json::num(w.len() as f64)),
        ("sessions", Json::num(sessions as f64)),
        ("turns", Json::num(turns as f64)),
        ("groups", Json::num(groups as f64)),
        ("k", Json::num(k as f64)),
        ("cheap", Json::Bool(cheap)),
        ("quick", Json::Bool(quick)),
        ("rows", Json::arr(json_rows)),
    ]);
    let json_path = "BENCH_routing.json";
    std::fs::write(json_path, format!("{doc}\n")).expect("write BENCH_routing.json");
    eprintln!(
        "bench_routing done in {:.2}s (cheap={cheap} quick={quick}); wrote {json_path}",
        t_start.elapsed().as_secs_f64()
    );
}
