//! Serving-layer throughput bench: one seeded hybrid workload through the
//! sharded ServingEngine at 1/2/4/8 workers. Shard state is session-local,
//! so every row serves identical hit/miss results (asserted) — the only
//! thing the worker count changes is wall-clock. Quick sizes by default;
//! paper-scale with CTXPILOT_FULL=1.

use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::{corpus_for, full_mode};
use contextpilot::pilot::PilotConfig;
use contextpilot::serve::{ServeConfig, ServingEngine};
use contextpilot::util::table::{reset_result_file, Table};
use contextpilot::workload::{hybrid, Dataset};

fn main() {
    let quick = !full_mode();
    reset_result_file("serving");
    let sessions = if quick { 192 } else { 768 };
    let turns = if quick { 3 } else { 6 };
    let n_shards = 8;
    let w = hybrid(Dataset::MtRag, sessions, turns, 10, 0x5E27E);
    let corpus = corpus_for(Dataset::MtRag);
    let t_start = std::time::Instant::now();

    let mut t = Table::new(
        &format!(
            "Serving throughput — {} requests ({} sessions x {} turns, MT-RAG) over {} shards",
            w.len(),
            sessions,
            turns,
            n_shards
        ),
        &["Workers", "Wall (s)", "Req/s", "Speedup vs 1w", "Hit ratio", "p50 TTFT", "p99 TTFT"],
    );
    let mut rps_1w = 0.0f64;
    let mut hits_1w: Option<u64> = None;
    let mut shard_table: Option<Table> = None;
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = ServeConfig::new(ModelSku::Qwen3_32B);
        cfg.n_shards = n_shards;
        cfg.n_workers = workers;
        cfg.capacity_tokens = 60_000;
        cfg.decode_tokens = 16;
        cfg.pilot = Some(PilotConfig::default());
        let engine = ServingEngine::new(cfg);
        let t0 = std::time::Instant::now();
        let served = engine.serve_batch(&w.requests, &corpus);
        let wall = t0.elapsed().as_secs_f64();
        let rps = served.len() as f64 / wall.max(1e-9);
        if workers == 1 {
            rps_1w = rps;
        }
        let (mut m, per) = engine.metrics();
        // determinism pin: worker count must not change cache behaviour
        let cached_total = m.total_cached_tokens;
        match hits_1w {
            None => hits_1w = Some(cached_total),
            Some(h) => assert_eq!(
                h, cached_total,
                "worker count changed cache hits: {h} vs {cached_total}"
            ),
        }
        t.row(vec![
            format!("{workers}"),
            format!("{wall:.3}"),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / rps_1w.max(1e-9)),
            format!("{:.1}%", m.hit_ratio() * 100.0),
            format!("{:.4}s", m.ttft.p50()),
            format!("{:.4}s", m.ttft.p99()),
        ]);
        if workers == 4 {
            let mut st = Table::new(
                "Per-shard stats (4 workers)",
                &[
                    "Shard",
                    "Served",
                    "Hit ratio",
                    "p50 TTFT",
                    "p99 TTFT",
                    "Max queue",
                    "Index nodes",
                    "Sessions",
                    "Resident tok",
                ],
            );
            for s in per {
                st.row(vec![
                    format!("{}", s.shard),
                    format!("{}", s.served),
                    format!("{:.1}%", s.hit_ratio * 100.0),
                    format!("{:.4}s", s.p50_ttft),
                    format!("{:.4}s", s.p99_ttft),
                    format!("{}", s.max_queue_depth),
                    format!("{}", s.index_nodes),
                    format!("{}", s.sessions),
                    format!("{}", s.resident_tokens),
                ]);
            }
            shard_table = Some(st);
        }
    }
    t.emit("serving");
    if let Some(st) = shard_table {
        st.emit("serving");
    }
    eprintln!(
        "bench_serving done in {:.2}s (quick={})",
        t_start.elapsed().as_secs_f64(),
        quick
    );
}
