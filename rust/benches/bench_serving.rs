//! Serving-layer throughput bench: one seeded hybrid workload through the
//! sharded api::Server at 1/2/4/8 workers, with chunked-prefill
//! admission off and on. Shard state is session-local, so every row serves
//! identical hit/miss results (asserted — neither worker count nor
//! chunking may change cache semantics); what moves is wall-clock and the
//! queue-aware TTFT of short requests. Quick sizes by default; paper-scale
//! with CTXPILOT_FULL=1. Machine-readable results land in
//! `BENCH_serving.json` so future PRs have a perf trajectory to compare
//! against, plus `BENCH_serving_telemetry.json` — the probe cell's run
//! telemetry in the exact `--metrics-out` schema, validated in-run.

use std::sync::Arc;

use contextpilot::api::Server;
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::{corpus_for, full_mode};
use contextpilot::obs::{run_telemetry, validate_telemetry};
use contextpilot::pilot::PilotConfig;
use contextpilot::types::ServedRequest;
use contextpilot::util::histogram::Summary;
use contextpilot::util::json::Json;
use contextpilot::util::prop::hit_miss_fingerprint;
use contextpilot::util::table::{reset_result_file, Table};
use contextpilot::workload::{hybrid, Dataset};

const N_SHARDS: usize = 8;

struct Row {
    workers: usize,
    prefill_chunk: Option<usize>,
    wall_s: f64,
    req_per_s: f64,
    hit_ratio: f64,
    p50_ttft: f64,
    p99_ttft: f64,
    p99_queued: f64,
    p99_queued_short: f64,
    cached_tokens: u64,
    prefill_chunks: u64,
}

/// p99 over the queue-aware TTFT of the "short request" class chunking
/// protects: requests whose uncached prefill fits a single chunk (they are
/// never split, so round-robin admission can only move them earlier).
/// Uses [`Summary`] so this column shares the percentile definition of
/// every other latency figure in the table.
fn p99_queued_short(served: &[ServedRequest], short_uncached_max: usize) -> f64 {
    let mut s = Summary::new();
    for r in served
        .iter()
        .filter(|r| r.prompt_tokens - r.cached_tokens <= short_uncached_max)
    {
        s.record(r.queued_ttft);
    }
    s.p99()
}

/// One sweep cell; `p99_queued_short` is left at 0 for the caller to fill
/// in once the chunk budget (and hence the short-request class) is known.
/// Also emits the cell's run-telemetry document
/// ([`contextpilot::obs::run_telemetry`]) so the bench exercises the same
/// schema the CLI's `--metrics-out` writes.
fn run_once(
    w: &contextpilot::workload::Workload,
    corpus: &Arc<contextpilot::corpus::Corpus>,
    workers: usize,
    prefill_chunk: Option<usize>,
) -> (Row, Vec<ServedRequest>, Json) {
    let server = Server::builder(ModelSku::Qwen3_32B)
        .shards(N_SHARDS)
        .workers(workers)
        .capacity(60_000)
        .decode_tokens(16)
        .pilot(PilotConfig::default())
        .prefill_chunk(prefill_chunk)
        .corpus(corpus.clone())
        .build()
        .expect("bench serve config is valid");
    let t0 = std::time::Instant::now();
    let served = server.serve_batch(&w.requests).expect("serve batch");
    let wall = t0.elapsed().as_secs_f64();
    let (mut m, per_shard) = server.metrics().expect("metrics");
    let row = Row {
        workers,
        prefill_chunk,
        wall_s: wall,
        req_per_s: served.len() as f64 / wall.max(1e-9),
        hit_ratio: m.hit_ratio(),
        p50_ttft: m.ttft.p50(),
        p99_ttft: m.ttft.p99(),
        p99_queued: m.p99_queued_ttft(),
        p99_queued_short: 0.0,
        cached_tokens: m.total_cached_tokens,
        prefill_chunks: m.total_prefill_chunks,
    };
    let telemetry = run_telemetry(
        "pilot",
        "mtrag-hybrid",
        &mut m,
        &per_shard,
        &server.counters(),
        0,
    );
    validate_telemetry(&telemetry).expect("bench telemetry matches the schema");
    (row, served, telemetry)
}

fn main() {
    let quick = !full_mode();
    reset_result_file("serving");
    let sessions = if quick { 192 } else { 768 };
    let turns = if quick { 3 } else { 6 };
    let w = hybrid(Dataset::MtRag, sessions, turns, 10, 0x5E27E);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let t_start = std::time::Instant::now();

    // the first sweep cell (1 worker, unchunked) doubles as the probe: its
    // prompt-length distribution fixes the chunk budget (below the longest
    // prompt, so chunking actually triggers). "Short" requests are those
    // whose uncached prefill fits one chunk — hit/miss results are
    // invariant across rows (asserted below), so the class is identical in
    // every run.
    let probe_cell = run_once(&w, &corpus, 1, None);
    let longest = probe_cell
        .1
        .iter()
        .map(|s| s.prompt_tokens)
        .max()
        .expect("non-empty workload");
    let chunk = (longest / 4).max(256);
    assert!(chunk < longest, "chunk budget must sit below the longest prompt");
    let n_short = probe_cell
        .1
        .iter()
        .filter(|s| s.prompt_tokens - s.cached_tokens <= chunk)
        .count();
    let mut probe_cell = Some(probe_cell);

    let mut t = Table::new(
        &format!(
            "Serving throughput — {} requests ({} sessions x {} turns, MT-RAG) over {} shards; chunk budget {} tok, {} single-chunk (short) requests",
            w.len(),
            sessions,
            turns,
            N_SHARDS,
            chunk,
            n_short
        ),
        &[
            "Workers",
            "Chunked",
            "Wall (s)",
            "Req/s",
            "Speedup vs 1w",
            "Hit ratio",
            "p50 TTFT",
            "p99 TTFT",
            "p99 queued",
            "p99 queued (short)",
        ],
    );
    let mut rows: Vec<Row> = Vec::new();
    let mut telemetry_doc: Option<Json> = None;
    let mut baseline_fingerprint: Option<Vec<(u64, usize, usize)>> = None;
    let mut rps_1w = vec![0.0f64; 2];
    for (ci, prefill_chunk) in [None, Some(chunk)].into_iter().enumerate() {
        for workers in [1usize, 2, 4, 8] {
            // the (1 worker, unchunked) cell was already run as the probe
            let (mut row, served, telemetry) = match (workers, prefill_chunk) {
                (1, None) => probe_cell.take().expect("probe consumed once"),
                _ => run_once(&w, &corpus, workers, prefill_chunk),
            };
            if telemetry_doc.is_none() {
                telemetry_doc = Some(telemetry);
            }
            row.p99_queued_short = p99_queued_short(&served, chunk);
            // determinism pin: neither worker count nor chunking may change
            // hit/miss results
            let fp = hit_miss_fingerprint(&served);
            match &baseline_fingerprint {
                None => baseline_fingerprint = Some(fp),
                Some(base) => assert_eq!(
                    *base, fp,
                    "workers={workers} chunk={prefill_chunk:?} changed hit/miss results"
                ),
            }
            if workers == 1 {
                rps_1w[ci] = row.req_per_s;
            }
            t.row(vec![
                format!("{}", row.workers),
                match row.prefill_chunk {
                    Some(c) => format!("{c}"),
                    None => "off".to_string(),
                },
                format!("{:.3}", row.wall_s),
                format!("{:.0}", row.req_per_s),
                format!("{:.2}x", row.req_per_s / rps_1w[ci].max(1e-9)),
                format!("{:.1}%", row.hit_ratio * 100.0),
                format!("{:.4}s", row.p50_ttft),
                format!("{:.4}s", row.p99_ttft),
                format!("{:.4}s", row.p99_queued),
                format!("{:.4}s", row.p99_queued_short),
            ]);
            rows.push(row);
        }
    }
    t.emit("serving");

    // chunked prefill must not hurt short requests' queue-aware tail —
    // with long prompts ahead of them it strictly helps (see the
    // engine_trait integration test for the strict single-queue pin)
    let unchunked_short = rows[0].p99_queued_short;
    let chunked_short = rows[4].p99_queued_short;
    assert!(
        chunked_short <= unchunked_short + 1e-9,
        "chunked p99 short {chunked_short} vs unchunked {unchunked_short}"
    );
    assert_eq!(
        rows[0].cached_tokens, rows[4].cached_tokens,
        "chunking changed cache totals"
    );
    eprintln!(
        "p99 queued TTFT (short requests): {unchunked_short:.4}s unchunked -> {chunked_short:.4}s chunked"
    );

    // machine-readable trajectory for future PRs
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("workers", Json::num(r.workers as f64)),
                (
                    "prefill_chunk",
                    r.prefill_chunk.map_or(Json::Null, |c| Json::num(c as f64)),
                ),
                ("wall_s", Json::num(r.wall_s)),
                ("req_per_s", Json::num(r.req_per_s)),
                ("hit_ratio", Json::num(r.hit_ratio)),
                ("p50_ttft_s", Json::num(r.p50_ttft)),
                ("p99_ttft_s", Json::num(r.p99_ttft)),
                ("p99_queued_ttft_s", Json::num(r.p99_queued)),
                ("p99_queued_ttft_short_s", Json::num(r.p99_queued_short)),
                ("cached_tokens", Json::num(r.cached_tokens as f64)),
                ("prefill_chunks", Json::num(r.prefill_chunks as f64)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("dataset", Json::str("mtrag-hybrid")),
        ("requests", Json::num(w.len() as f64)),
        ("sessions", Json::num(sessions as f64)),
        ("turns", Json::num(turns as f64)),
        ("shards", Json::num(N_SHARDS as f64)),
        ("chunk_tokens", Json::num(chunk as f64)),
        ("short_uncached_max_tokens", Json::num(chunk as f64)),
        ("short_requests", Json::num(n_short as f64)),
        ("quick", Json::Bool(quick)),
        ("rows", Json::arr(json_rows)),
    ]);
    let json_path = "BENCH_serving.json";
    std::fs::write(json_path, format!("{doc}\n")).expect("write BENCH_serving.json");
    // the probe cell's run-telemetry document (already validated), in the
    // exact shape the CLI's --metrics-out writes
    let telemetry = telemetry_doc.expect("probe cell ran");
    let telemetry_path = "BENCH_serving_telemetry.json";
    std::fs::write(telemetry_path, format!("{telemetry}\n"))
        .expect("write BENCH_serving_telemetry.json");
    eprintln!(
        "bench_serving done in {:.2}s (quick={quick}); wrote {json_path} and {telemetry_path}",
        t_start.elapsed().as_secs_f64()
    );
}
