//! Bench target regenerating the paper's table6 (custom harness; see
//! DESIGN.md §3 experiment index). Quick sizes by default; paper-scale
//! with CTXPILOT_FULL=1.

use contextpilot::experiments::{table6, full_mode};
use contextpilot::util::table::reset_result_file;

fn main() {
    let quick = !full_mode();
    reset_result_file("table6");
    let t0 = std::time::Instant::now();
    for table in table6::run(quick) {
        table.emit("table6");
    }
    eprintln!("bench_table6 done in {:.2}s (quick={})", t0.elapsed().as_secs_f64(), quick);
}
