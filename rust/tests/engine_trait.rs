//! Integration: the engine-generic serving layer.
//!
//! The `InferenceEngine` trait is the §4.1 proxy↔engine contract; these
//! tests pin the property that makes it a *contract*: the serving layer's
//! behaviour toward the engine — which requests it serves, in which order,
//! and how eviction callbacks flow — is decided by the proxy pipeline and
//! is identical for any backend behind the trait. Plus the chunked-prefill
//! admission acceptance: chunking must never change cache semantics, and
//! must improve the queue-aware tail latency of short requests stuck
//! behind long prefills.

use std::sync::Arc;

use contextpilot::api::{Server, ServerBuilder};
use contextpilot::corpus::Corpus;
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::corpus_for;
use contextpilot::serve::ServeConfig;
use contextpilot::types::{BlockId, QueryId, Request, RequestId, ServedRequest, SessionId};
use contextpilot::util::prng::Rng;
use contextpilot::util::prop::{
    check, gen_requests, hit_miss_fingerprint, Config, EngineCall, EngineLog, MockEngine,
    RecordingEngine,
};
use contextpilot::workload::{hybrid, Dataset};

fn base_cfg(shards: usize) -> ServeConfig {
    let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
    cfg.n_shards = shards;
    // single worker: shard queues drain in shard order, so the interaction
    // logs below are strictly deterministic
    cfg.n_workers = 1;
    // roomy KV budget: no capacity evictions, so engine feedback cannot
    // steer the pilot and the two backends face identical pipelines
    cfg.capacity_tokens = 1 << 22;
    cfg.decode_tokens = 8;
    cfg
}

/// Serve `reqs` through a recorded server built by `factory`-per-shard
/// engines, returning the proxy→engine interaction sequence.
fn record_run<E, F>(
    cfg: ServeConfig,
    reqs: &[Request],
    corpus: &Arc<Corpus>,
    mut factory: F,
) -> Vec<EngineCall>
where
    E: contextpilot::engine::InferenceEngine,
    F: FnMut(&ServeConfig) -> E,
{
    let log = EngineLog::default();
    let server = {
        let log = log.clone();
        let mut tag = 0usize;
        ServerBuilder::from_config(cfg)
            .corpus(corpus.clone())
            .build_with(move |c| {
                let e = RecordingEngine {
                    inner: factory(c),
                    shard_tag: tag,
                    log: log.clone(),
                };
                tag += 1;
                e
            })
            .expect("recorded serve config is valid")
    };
    server.serve_batch(reqs).expect("serve batch");
    let calls = log.lock().expect("log poisoned");
    calls.clone()
}

// ---- satellite: MockEngine property ---------------------------------------

#[test]
fn mock_and_sim_issue_identical_engine_call_sequences() {
    // For any workload, ServingEngine<MockEngine> and ServingEngine<SimEngine>
    // must issue the same (request, evict-callback) sequence to their
    // engines: partitioning, Alg.-5 scheduling and §4.1 plumbing live
    // above the trait and may not depend on the backend.
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    check(
        "serving layer is engine-agnostic",
        Config {
            cases: 10,
            base_seed: 0x7A17,
            max_size: 40,
        },
        |rng: &mut Rng, size| {
            let reqs = gen_requests(rng, size.max(6), 9, 6, corpus.len());
            let cfg = base_cfg(3);
            let sim_calls =
                record_run(cfg.clone(), &reqs, &corpus, |c: &ServeConfig| c.sim_engine());
            let mock_calls = record_run(cfg, &reqs, &corpus, |_c: &ServeConfig| {
                MockEngine::new(16, 1 << 30)
            });
            if sim_calls.len() != reqs.len() {
                return Err(format!(
                    "sim engine saw {} serves for {} requests",
                    sim_calls.len(),
                    reqs.len()
                ));
            }
            if sim_calls != mock_calls {
                return Err(format!(
                    "engine-call sequences diverged:\n sim: {sim_calls:?}\n mock: {mock_calls:?}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn mock_engine_eviction_callbacks_prune_the_pilot_index() {
    // a tiny mock FIFO capacity forces per-serve evictions; the shard must
    // feed them into its pilot, keeping the context index bounded
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let mut rng = Rng::new(0xEE);
    let reqs = gen_requests(&mut rng, 60, 6, 6, corpus.len());

    let mock_server = |fifo_tokens: usize| {
        ServerBuilder::from_config(base_cfg(1))
            .corpus(corpus.clone())
            .build_with(|_c| MockEngine::new(16, fifo_tokens))
            .expect("mock serve config is valid")
    };
    let roomy = mock_server(1 << 30);
    roomy.serve_batch(&reqs).expect("serve");
    let (_, roomy_stats) = roomy.metrics().expect("metrics");

    let tight = mock_server(400);
    tight.serve_batch(&reqs).expect("serve");
    let (_, tight_stats) = tight.metrics().expect("metrics");

    assert_eq!(roomy_stats[0].served, 60);
    assert_eq!(tight_stats[0].served, 60);
    assert!(
        tight_stats[0].index_nodes < roomy_stats[0].index_nodes,
        "evictions must prune the index: tight {} vs roomy {}",
        tight_stats[0].index_nodes,
        roomy_stats[0].index_nodes
    );

    // external §4.1 eviction of everything prunes each index to its root
    let ids: Vec<RequestId> = reqs.iter().map(|r| r.id).collect();
    roomy.on_evict(&ids).expect("evict");
    let (_, per) = roomy.metrics().expect("metrics");
    assert!(per[0].index_nodes <= 1, "kept {} nodes", per[0].index_nodes);
}

// ---- acceptance: chunked-prefill admission --------------------------------

#[test]
fn chunking_never_changes_cache_semantics() {
    let w = hybrid(Dataset::MtRag, 20, 3, 8, 0xC4A4);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |chunk: Option<usize>| {
        let mut cfg = base_cfg(4);
        cfg.n_workers = 4;
        cfg.capacity_tokens = 40_000;
        cfg.prefill_chunk = chunk;
        let server = ServerBuilder::from_config(cfg)
            .corpus(corpus.clone())
            .build()
            .expect("chunked serve config is valid");
        hit_miss_fingerprint(&server.serve_batch(&w.requests).expect("serve"))
    };
    let base = run(None);
    for chunk in [64usize, 300, 1_000, 10_000] {
        assert_eq!(run(Some(chunk)), base, "chunk={chunk} changed hit/miss results");
    }
}

#[test]
fn chunking_improves_short_request_tail_latency() {
    // single shard, baseline mode, cold cache: a short request queued
    // behind a long prefill. Unchunked it waits out the whole prefill;
    // chunked it is admitted after one chunk.
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let req = |id: u64, session: u32, ids: &[u32]| Request {
        id: RequestId(id),
        session: SessionId(session),
        turn: 0,
        context: ids.iter().map(|&i| BlockId(i)).collect(),
        query: QueryId(id),
    };
    let batch = vec![
        req(1, 1, &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]),
        req(2, 2, &[20]),
    ];
    let run = |chunk: Option<usize>| {
        let mut cfg = base_cfg(1);
        cfg.pilot = None;
        cfg.prefill_chunk = chunk;
        let server = ServerBuilder::from_config(cfg)
            .corpus(corpus.clone())
            .build()
            .expect("baseline serve config is valid");
        server.serve_batch(&batch).expect("serve")
    };
    let plain = run(None);
    let chunked = run(Some(64));
    // identical serving outcomes...
    assert_eq!(hit_miss_fingerprint(&plain), hit_miss_fingerprint(&chunked));
    // ...split prefill for the long request only...
    assert!(chunked[0].prefill_chunks > 1, "long prompt must chunk");
    assert_eq!(chunked[1].prefill_chunks, 1);
    assert_eq!(plain[0].prefill_chunks, 1);
    // ...and a strictly better queue-aware tail for the short request
    assert!(
        chunked[1].queued_ttft < plain[1].queued_ttft,
        "short request not unblocked: chunked {} vs plain {}",
        chunked[1].queued_ttft,
        plain[1].queued_ttft
    );
    // conservation: total engine occupancy is unchanged
    let span = |v: &[ServedRequest]| v.iter().map(|s| s.queued_ttft).fold(0.0f64, f64::max);
    assert!((span(&plain) - span(&chunked)).abs() < 1e-9);
}

#[test]
fn streaming_path_reports_singleton_admission() {
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let mut cfg = base_cfg(2);
    cfg.prefill_chunk = Some(64);
    let server: Server = ServerBuilder::from_config(cfg)
        .corpus(corpus)
        .build()
        .expect("serve config is valid");
    let r = Request {
        id: RequestId(5),
        session: SessionId(3),
        turn: 0,
        context: (1u32..=10).map(BlockId).collect(),
        query: QueryId(5),
    };
    let served = server.serve_one(&r).expect("serve");
    // a singleton wave has nothing to interleave with: queued == raw
    // TTFT, but the chunk accounting still reflects the split — the
    // ticket path must preserve both
    assert!((served.queued_ttft - served.ttft).abs() < 1e-12);
    assert!(served.prefill_chunks > 1);
}
