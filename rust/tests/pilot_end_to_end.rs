//! Integration: the full coordinator pipeline (workload -> pilot ->
//! engine -> metrics) across every workload generator and system.

use contextpilot::engine::ModelSku;
use contextpilot::experiments::{corpus_for, run_system, RunConfig, SystemKind};
use contextpilot::pilot::{ContextPilot, PilotConfig};
use contextpilot::workload::*;

#[test]
fn every_workload_serves_through_every_system() {
    let cases: Vec<(Dataset, Workload, bool)> = vec![
        (Dataset::MultihopRag, multi_session(Dataset::MultihopRag, 40, 10, 1), true),
        (Dataset::MtRag, multi_turn(Dataset::MtRag, 10, 8, 2), false),
        (Dataset::MtRag, hybrid(Dataset::MtRag, 4, 4, 8, 3), false),
        (Dataset::LoCoMo, mem0(3, 6, 10, 4), false),
        (
            Dataset::MultihopRag,
            chain_of_agents(Dataset::MultihopRag, 5, 3, 4, 5),
            false,
        ),
    ];
    for (dataset, w, offline) in cases {
        for system in SystemKind::all_default() {
            let corpus = corpus_for(dataset);
            let mut cfg = RunConfig::for_dataset(ModelSku::Qwen3_4B, dataset);
            cfg.offline = offline;
            let m = run_system(&system, &w, &corpus, &cfg);
            assert_eq!(m.len(), w.len(), "{} on {:?}", system.name(), dataset);
            assert!(m.mean_quality() > 0.3, "{} quality collapsed", system.name());
            assert!(m.prefill_throughput() > 0.0);
        }
    }
}

#[test]
fn pilot_index_stays_consistent_under_churn() {
    // tight cache -> constant eviction -> on_evict pruning must never
    // corrupt the index
    let dataset = Dataset::MultihopRag;
    let corpus = corpus_for(dataset);
    let w = multi_session(dataset, 120, 10, 9);
    let mut pilot = ContextPilot::new(PilotConfig::default());
    pilot.build_offline(&w.requests);
    let mut engine = contextpilot::engine::SimEngine::new(
        ModelSku::Qwen3_4B.profile(),
        contextpilot::engine::ReusePolicy::RadixPrefix,
        6_000, // very tight KV budget
    );
    let quality = contextpilot::quality::QualityModel::new(
        contextpilot::quality::ModelEra::Modern,
        true,
    );
    let outputs = pilot.process_batch(&w.requests, &corpus);
    let mut total_evicted = 0usize;
    for out in outputs {
        let (_, evicted) = engine.serve(&out.request, &out.prompt, &corpus, &quality, 8);
        total_evicted += evicted.len();
        pilot.on_evict(&evicted);
        pilot.index.check_invariants().unwrap();
    }
    assert!(total_evicted > 0, "tight budget must churn");
}

#[test]
fn offline_and_online_modes_agree_on_aligned_permutations() {
    let dataset = Dataset::Qasper;
    let corpus = corpus_for(dataset);
    let w = multi_session(dataset, 30, 8, 11);
    // offline
    let mut off = ContextPilot::new(PilotConfig::default());
    off.build_offline(&w.requests);
    let off_out = off.process_batch(&w.requests, &corpus);
    // online
    let mut on = ContextPilot::new(PilotConfig::default());
    let on_out = on.process_batch(&w.requests, &corpus);
    // outputs are scheduled (reordered): match by request id
    for a in &off_out {
        let b = on_out
            .iter()
            .find(|o| o.request.id == a.request.id)
            .expect("request present in both modes");
        let mut pa = a.aligned.clone();
        let mut pb = b.aligned.clone();
        pa.sort_unstable();
        pb.sort_unstable();
        // both modes are permutations of the same retrieval
        assert_eq!(pa, pb);
    }
}
