//! Integration: the observability layer (`contextpilot::obs`) through the
//! facade. The contracts under test:
//!
//! 1. the merged lifecycle trace is deterministic and worker-count
//!    invariant (events are stamped on the shards' virtual clocks, not
//!    wall time);
//! 2. with observability off — the default — serving output is
//!    bit-identical to a server that never heard of the obs layer: same
//!    hit/miss fingerprints, same TTFT bits, zero trace events;
//! 3. the always-on counter registry mirrors `RunMetrics` exactly;
//! 4. both exporters produce JSON that round-trips through `util::json`,
//!    and the telemetry document passes its own validator.

use std::sync::Arc;

use contextpilot::api::{ObsConfig, Server, ServerBuilder};
use contextpilot::corpus::Corpus;
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::corpus_for;
use contextpilot::obs::{chrome_trace, run_telemetry, validate_telemetry, TraceEvent};
use contextpilot::serve::ServeConfig;
use contextpilot::types::ServedRequest;
use contextpilot::util::json::Json;
use contextpilot::util::prop::hit_miss_fingerprint;
use contextpilot::workload::{hybrid, Dataset, Workload};

fn serve_cfg(shards: usize, workers: usize, trace: bool) -> ServeConfig {
    let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
    cfg.n_shards = shards;
    cfg.n_workers = workers;
    cfg.capacity_tokens = 40_000;
    cfg.decode_tokens = 8;
    cfg.prefill_chunk = Some(512);
    if trace {
        cfg.obs = ObsConfig::tracing();
    }
    cfg
}

fn server(cfg: ServeConfig, corpus: &Arc<Corpus>) -> Server {
    ServerBuilder::from_config(cfg)
        .corpus(corpus.clone())
        .build()
        .expect("test serve config is valid")
}

fn workload() -> Workload {
    hybrid(Dataset::MtRag, 16, 3, 8, 0x0B5)
}

/// The exact bits of every latency output — any nondeterminism or
/// obs-induced perturbation shows up here.
fn ttft_bits(served: &[ServedRequest]) -> Vec<(u64, u64)> {
    served
        .iter()
        .map(|s| (s.ttft.to_bits(), s.queued_ttft.to_bits()))
        .collect()
}

fn counter(counters: &[(&'static str, u64)], name: &str) -> u64 {
    counters
        .iter()
        .find(|(n, _)| *n == name)
        .map(|&(_, v)| v)
        .unwrap_or_else(|| panic!("no counter named {name}"))
}

#[test]
fn trace_stream_is_worker_count_invariant() {
    let w = workload();
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |workers: usize| -> Vec<TraceEvent> {
        let server = server(serve_cfg(4, workers, true), &corpus);
        server.serve_batch(&w.requests).expect("serve");
        server.trace_events().expect("trace")
    };
    let base = run(1);
    assert!(!base.is_empty(), "traced run must emit events");
    for name in ["admitted", "placed", "queued", "prefill_chunk", "resolved"] {
        assert!(
            base.iter().any(|e| e.kind.name() == name),
            "missing lifecycle phase {name}"
        );
    }
    for w2 in base.windows(2) {
        assert!(w2[0].t <= w2[1].t, "merged stream must be time-ordered");
    }
    for workers in [2usize, 4, 8] {
        assert_eq!(run(workers), base, "workers={workers} changed the trace");
    }
}

#[test]
fn disabled_observability_serves_bit_identically() {
    let w = workload();
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |trace: bool| {
        let server = server(serve_cfg(4, 2, trace), &corpus);
        let served = server.serve_batch(&w.requests).expect("serve");
        let events = server.trace_events().expect("trace");
        (hit_miss_fingerprint(&served), ttft_bits(&served), events)
    };
    let (fp_off, bits_off, trace_off) = run(false);
    let (fp_on, bits_on, trace_on) = run(true);
    assert!(trace_off.is_empty(), "no tracer when observability is off");
    assert!(!trace_on.is_empty(), "tracer on must record the run");
    assert_eq!(fp_on, fp_off, "tracing changed hit/miss results");
    assert_eq!(bits_on, bits_off, "tracing changed TTFT bits");
}

#[test]
fn registry_mirrors_run_metrics() {
    let w = workload();
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    // observability off: the registry runs regardless
    let server = server(serve_cfg(4, 2, false), &corpus);
    server.serve_batch(&w.requests).expect("serve");
    let (m, per_shard) = server.metrics().expect("metrics");
    let c = server.counters();
    assert_eq!(counter(&c, "requests_served"), m.len() as u64);
    assert_eq!(counter(&c, "prompt_tokens"), m.total_prompt_tokens);
    assert_eq!(counter(&c, "cached_tokens"), m.total_cached_tokens);
    assert_eq!(counter(&c, "hot_hit_tokens"), m.total_hot_hit_tokens);
    assert_eq!(counter(&c, "warm_hit_tokens"), m.total_warm_hit_tokens);
    assert_eq!(counter(&c, "cold_hit_tokens"), m.total_cold_hit_tokens);
    assert_eq!(counter(&c, "prefill_chunks"), m.total_prefill_chunks);
    let max_depth = per_shard.iter().map(|s| s.max_queue_depth).max();
    assert_eq!(counter(&c, "max_queue_depth"), max_depth.unwrap_or(0) as u64);
    assert!(counter(&c, "queue_waves") > 0, "waves must be counted");
    assert!(counter(&c, "placement_waves") > 0);
}

#[test]
fn exports_round_trip_and_validate() {
    let w = workload();
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let server = server(serve_cfg(4, 2, true), &corpus);
    server.serve_batch(&w.requests).expect("serve");
    let events = server.trace_events().expect("trace");

    let trace = chrome_trace(&events);
    let parsed = Json::parse(&trace.to_string()).expect("chrome trace parses back");
    let rows = parsed.get("traceEvents").as_arr().expect("traceEvents array");
    assert_eq!(rows.len(), events.len());

    let (mut m, per_shard) = server.metrics().expect("metrics");
    let telemetry = run_telemetry(
        "pilot",
        "mtrag",
        &mut m,
        &per_shard,
        &server.counters(),
        events.len(),
    );
    validate_telemetry(&telemetry).expect("telemetry validates");
    let reparsed = Json::parse(&telemetry.to_string()).expect("telemetry parses back");
    validate_telemetry(&reparsed).expect("round-tripped telemetry still validates");
    assert_eq!(reparsed.get("requests").as_usize(), Some(w.requests.len()));
    assert_eq!(
        reparsed.get("counters").get("requests_served").as_u64(),
        Some(w.requests.len() as u64)
    );
}
