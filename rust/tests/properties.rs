//! Cross-module property tests on the DESIGN.md §6 invariants:
//! alignment/scheduling permutations, index round-trips, dedup safety.

use contextpilot::align::align_to_prefix;
use contextpilot::corpus::{Corpus, CorpusConfig};
use contextpilot::dedup::{dedup_context, DedupConfig};
use contextpilot::index::build::build_clustered;
use contextpilot::index::tree::ContextIndex;
use contextpilot::pilot::{ContextPilot, PilotConfig};
use contextpilot::schedule::schedule_by_paths;
use contextpilot::tokenizer::Tokenizer;
use contextpilot::types::*;
use contextpilot::util::prng::Rng;
use contextpilot::util::prop::{check, gen_distinct_ids, Config};

fn blocks(ids: Vec<usize>) -> Context {
    ids.into_iter().map(|i| BlockId(i as u32)).collect()
}

#[test]
fn clustered_build_properties() {
    check(
        "clustered build: paths round-trip, alignment is a permutation",
        Config {
            cases: 48,
            base_seed: 0xB11D,
            max_size: 60,
        },
        |rng: &mut Rng, size| {
            let n = size.max(2).min(60);
            let inputs: Vec<(RequestId, Context)> = (0..n)
                .map(|i| {
                    let k = rng.range(1, 10);
                    (
                        RequestId(i as u64),
                        blocks(rng.sample_indices(40, k.min(40))),
                    )
                })
                .collect();
            let r = build_clustered(&inputs, 0.001);
            r.index.check_invariants()?;
            for ((_, orig), (leaf, aligned, path)) in inputs.iter().zip(&r.placed) {
                let mut a = orig.clone();
                let mut b = aligned.clone();
                a.sort_unstable();
                b.sort_unstable();
                if a != b {
                    return Err("aligned not a permutation".to_string());
                }
                if r.index.traverse(path) != Some(*leaf) {
                    return Err("path round-trip failed".to_string());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn incremental_search_insert_evict_properties() {
    check(
        "incremental index: search/insert/evict keep invariants",
        Config {
            cases: 48,
            base_seed: 0x1D8,
            max_size: 80,
        },
        |rng: &mut Rng, size| {
            let mut ix = ContextIndex::new(0.001);
            let mut live: Vec<RequestId> = Vec::new();
            for i in 0..size {
                if !live.is_empty() && rng.chance(0.25) {
                    let v = live.swap_remove(rng.below(live.len()));
                    ix.on_evict(&[v]);
                } else {
                    let c = blocks(gen_distinct_ids(rng, 8, 30));
                    if c.is_empty() {
                        continue;
                    }
                    let req = RequestId(i as u64);
                    let found = ix.search(&c);
                    ix.insert_at(&found, c, req);
                    live.push(req);
                }
                ix.check_invariants()?;
            }
            // evict everything: only the root survives
            ix.on_evict(&live);
            if ix.len_alive() != 1 {
                return Err(format!("{} nodes after full eviction", ix.len_alive()));
            }
            Ok(())
        },
    );
}

#[test]
fn schedule_properties_on_pilot_paths() {
    check(
        "scheduling real pilot paths is a contiguous-group permutation",
        Config {
            cases: 32,
            base_seed: 0x5C4E,
            max_size: 40,
        },
        |rng: &mut Rng, size| {
            let corpus = Corpus::generate(
                &CorpusConfig {
                    n_docs: 50,
                    ..Default::default()
                },
                &Tokenizer::default(),
            );
            let mut pilot = ContextPilot::new(PilotConfig::default());
            let reqs: Vec<Request> = (0..size.max(1))
                .map(|i| Request {
                    id: RequestId(i as u64),
                    session: SessionId(i as u32),
                    turn: 0,
                    context: {
                        let k = rng.range(1, 8);
                        blocks(rng.sample_indices(50, k))
                    },
                    query: QueryId(i as u64),
                })
                .collect();
            let outs = pilot.process_batch(&reqs, &corpus);
            let paths: Vec<Vec<usize>> = outs.iter().map(|o| o.path.clone()).collect();
            let order = schedule_by_paths(&paths);
            let mut sorted = order.clone();
            sorted.sort_unstable();
            if sorted != (0..paths.len()).collect::<Vec<_>>() {
                return Err("not a permutation".to_string());
            }
            Ok(())
        },
    );
}

#[test]
fn dedup_properties() {
    let corpus = Corpus::generate(
        &CorpusConfig {
            n_docs: 60,
            ..Default::default()
        },
        &Tokenizer::default(),
    );
    check(
        "dedup: no invention, refs only to seen blocks, order preserved",
        Config {
            cases: 64,
            base_seed: 0xDED,
            max_size: 12,
        },
        |rng: &mut Rng, size| {
            let mut ix = ContextIndex::new(0.001);
            let session = SessionId(rng.below(1000) as u32);
            let cfg = DedupConfig::default();
            let mut seen: std::collections::HashSet<BlockId> = Default::default();
            for turn in 0..3 {
                let c = blocks(gen_distinct_ids(rng, size.max(1), 60));
                let (segs, _) = dedup_context(&mut ix, session, &c, &corpus, &cfg);
                let mentioned: Vec<BlockId> = segs
                    .iter()
                    .filter_map(|s| match s {
                        Segment::Block(b)
                        | Segment::LocationRef(b)
                        | Segment::PartialBlock { block: b, .. } => Some(*b),
                        _ => None,
                    })
                    .collect();
                if mentioned != c {
                    return Err(format!("turn {turn}: block order/coverage changed"));
                }
                for s in &segs {
                    if let Segment::LocationRef(b) = s {
                        if !seen.contains(b) {
                            return Err(format!("turn {turn}: dangling ref {b}"));
                        }
                    }
                }
                seen.extend(c.iter().copied());
            }
            Ok(())
        },
    );
}

#[test]
fn align_to_prefix_properties() {
    check(
        "align_to_prefix: permutation + shared blocks lead in prefix order",
        Config {
            cases: 256,
            base_seed: 0xA11,
            max_size: 24,
        },
        |rng: &mut Rng, size| {
            let c = blocks(gen_distinct_ids(rng, size.max(1), 48));
            let p = blocks(gen_distinct_ids(rng, size.max(1), 48));
            let out = align_to_prefix(&p, &c);
            let mut a = c.clone();
            let mut b = out.clone();
            a.sort_unstable();
            b.sort_unstable();
            if a != b {
                return Err("not a permutation".into());
            }
            // shared blocks appear first, in prefix order
            let shared: Vec<BlockId> =
                p.iter().copied().filter(|x| c.contains(x)).collect();
            if out[..shared.len()] != shared[..] {
                return Err("shared prefix not leading".into());
            }
            Ok(())
        },
    );
}
