//! Crash-recovery acceptance pins for the durable serving path
//! (`--state-dir` / `ServerBuilder::state_dir` / `resume_from`).
//!
//! What must hold across a restart:
//!  * serve → checkpoint → drop → resume, then replaying recurring
//!    contexts reports **cold-tier hits** (promotion at reload cost), not
//!    a full re-prefill — the whole point of the durable cold tier;
//!  * the resumed run's hit/miss results are **bit-identical** to a run
//!    that checkpointed but never restarted (recovery is invisible to
//!    serving semantics);
//!  * session → shard pins survive the restart (warm-state snapshot);
//!  * the in-memory and file-backed [`Storage`] backends serve
//!    identically (the mirror never feeds back into a live run);
//!  * a damaged state directory fails `build()` with a **typed error**
//!    ([`Error::CorruptSnapshot`] / [`Error::Storage`]) — never a panic.
//!
//! Admission is pinned to [`AdmissionPolicy::Always`]: the cost-aware
//! gate refuses short spans, and these workloads care about *where*
//! content lands, not whether reloading it is profitable. Shelves are
//! roomy so no run diverges through capacity-pressure pruning.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use contextpilot::api::{AdmissionPolicy, Error, ModelSku, Response, Server, TierConfig};
use contextpilot::corpus::{Corpus, CorpusConfig};
use contextpilot::engine::SimEngine;
use contextpilot::tokenizer::Tokenizer;
use contextpilot::types::{BlockId, QueryId, Request, RequestId, SessionId};

fn corpus() -> Arc<Corpus> {
    Arc::new(Corpus::generate(
        &CorpusConfig {
            n_docs: 24,
            ..Default::default()
        },
        &Tokenizer::default(),
    ))
}

fn tiers() -> TierConfig {
    let mut t = TierConfig::new(500_000, 2_000_000);
    t.admission = AdmissionPolicy::Always;
    t
}

fn tempdir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ctxpilot-recovery-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn req(id: u64, session: u32, turn: u32, blocks: &[u32]) -> Request {
    Request {
        id: RequestId(id),
        session: SessionId(session),
        turn,
        context: blocks.iter().map(|&b| BlockId(b)).collect(),
        query: QueryId(id),
    }
}

/// Recurring-session waves: 6 sessions, each with a fixed signature of
/// overlapping context blocks, revisited over 3 turns. `id_base` /
/// `session_base` shift ids so replays after a restart use fresh request
/// ids and fresh sessions (engine conversation history is deliberately
/// not durable — recovered KV serves *new* sessions over old content).
fn waves(id_base: u64, session_base: u32) -> Vec<Vec<Request>> {
    (0..3u32)
        .map(|turn| {
            (0..6u32)
                .map(|s| {
                    let blocks = [3 * s + 1, 3 * s + 2, 3 * s + 3, (s % 4) + 1];
                    req(
                        id_base + u64::from(turn) * 6 + u64::from(s) + 1,
                        session_base + s + 1,
                        turn,
                        &blocks,
                    )
                })
                .collect()
        })
        .collect()
}

/// The serving-semantics fingerprint: per request, the token accounting
/// and the hot/warm/cold split, with TTFT compared bit-for-bit.
fn fingerprint(responses: &[Response]) -> Vec<(u64, usize, usize, usize, usize, usize, u64)> {
    responses
        .iter()
        .map(|r| {
            (
                r.request.id.0,
                r.prompt_tokens,
                r.cached_tokens,
                r.tier_hits.hbm,
                r.tier_hits.dram,
                r.tier_hits.ssd,
                r.ttft.to_bits(),
            )
        })
        .collect()
}

fn durable_server(c: &Arc<Corpus>, dir: &Path, resume: bool) -> Server<SimEngine> {
    let b = Server::builder(ModelSku::Qwen3_4B)
        .shards(1)
        .workers(1)
        .capacity(4_000)
        .decode_tokens(8)
        .tier_config(tiers())
        .corpus(c.clone());
    let b = if resume {
        b.resume_from(dir)
    } else {
        b.state_dir(dir)
    };
    b.build().expect("durable build")
}

#[test]
fn resume_serves_recurring_contexts_from_the_cold_tier() {
    let dir = tempdir("resume");
    let c = corpus();

    // run 1: serve the recurring waves, checkpoint, "crash"
    let server = durable_server(&c, &dir, false);
    for wave in waves(0, 0) {
        server.serve_batch(&wave).expect("serve");
    }
    server.checkpoint().expect("checkpoint");
    drop(server);

    // run 2: resume and replay the same contexts as brand-new sessions
    let resumed = durable_server(&c, &dir, true);
    let mut replay = Vec::new();
    for wave in waves(1_000, 100) {
        replay.extend(resumed.serve_batch(&wave).expect("serve resumed"));
    }
    let cold: usize = replay.iter().map(|r| r.tier_hits.dram + r.tier_hits.ssd).sum();
    let cached: usize = replay.iter().map(|r| r.cached_tokens).sum();
    assert!(
        cold > 0,
        "recurring contexts must promote from the recovered cold tier, not re-prefill"
    );
    assert!(cached >= cold);

    // warm state survived: run-1 sessions are still pinned, a session the
    // server never saw is a typed miss
    assert_eq!(resumed.session_shard(SessionId(1)).expect("pin survives"), 0);
    assert!(matches!(
        resumed.session_shard(SessionId(999)),
        Err(Error::UnknownSession(_))
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resumed_run_matches_a_never_restarted_run_bit_for_bit() {
    let c = corpus();

    // interrupted: serve → checkpoint → drop → resume → replay
    let dir_a = tempdir("interrupted");
    let server = durable_server(&c, &dir_a, false);
    for wave in waves(0, 0) {
        server.serve_batch(&wave).expect("serve");
    }
    server.checkpoint().expect("checkpoint");
    drop(server);
    let resumed = durable_server(&c, &dir_a, true);
    let mut interrupted = Vec::new();
    for wave in waves(1_000, 100) {
        interrupted.extend(resumed.serve_batch(&wave).expect("serve resumed"));
    }

    // ground truth: same checkpoint (the spill is part of the semantics),
    // but the process never dies
    let dir_b = tempdir("uninterrupted");
    let server = durable_server(&c, &dir_b, false);
    for wave in waves(0, 0) {
        server.serve_batch(&wave).expect("serve");
    }
    server.checkpoint().expect("checkpoint");
    let mut uninterrupted = Vec::new();
    for wave in waves(1_000, 100) {
        uninterrupted.extend(server.serve_batch(&wave).expect("serve"));
    }

    assert_eq!(
        fingerprint(&interrupted),
        fingerprint(&uninterrupted),
        "a restart must be invisible to hit/miss results and TTFT"
    );
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn mem_and_file_backed_storage_serve_identically() {
    let c = corpus();
    let build_ephemeral = || {
        Server::builder(ModelSku::Qwen3_4B)
            .shards(2)
            .workers(1)
            .capacity(4_000)
            .decode_tokens(8)
            .tier_config(tiers())
            .corpus(c.clone())
            .build()
            .expect("ephemeral build")
    };
    let dir = tempdir("mirror");
    let durable = Server::builder(ModelSku::Qwen3_4B)
        .shards(2)
        .workers(1)
        .capacity(4_000)
        .decode_tokens(8)
        .tier_config(tiers())
        .corpus(c.clone())
        .state_dir(&dir)
        .build()
        .expect("durable build");

    let ephemeral = build_ephemeral();
    let mut mem = Vec::new();
    let mut file = Vec::new();
    for wave in waves(0, 0) {
        mem.extend(ephemeral.serve_batch(&wave).expect("serve mem"));
        file.extend(durable.serve_batch(&wave).expect("serve file"));
    }
    assert_eq!(
        fingerprint(&mem),
        fingerprint(&file),
        "the file mirror must never feed back into live serving"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn damaged_state_is_a_typed_error_never_a_panic() {
    let dir = tempdir("damage");
    let c = corpus();
    let build_resume = |shards: usize| {
        Server::builder(ModelSku::Qwen3_4B)
            .shards(shards)
            .workers(1)
            .capacity(4_000)
            .decode_tokens(8)
            .tier_config(tiers())
            .corpus(c.clone())
            .resume_from(&dir)
            .build()
    };

    // no state dir at all: an I/O problem, not corruption
    assert!(matches!(build_resume(2).unwrap_err(), Error::Storage(_)));

    // lay down a good checkpoint to damage
    {
        let server = Server::builder(ModelSku::Qwen3_4B)
            .shards(2)
            .workers(1)
            .capacity(4_000)
            .decode_tokens(8)
            .tier_config(tiers())
            .corpus(c.clone())
            .state_dir(&dir)
            .build()
            .expect("durable build");
        for wave in waves(0, 0) {
            server.serve_batch(&wave).expect("serve");
        }
        server.checkpoint().expect("checkpoint");
    }
    let snapshot = dir.join("snapshot.json");
    let good = std::fs::read_to_string(&snapshot).unwrap();

    // truncated mid-record (crash while writing would be caught by the
    // tmp+rename protocol, but a damaged disk is not)
    std::fs::write(&snapshot, &good[..good.len() / 2]).unwrap();
    assert!(matches!(build_resume(2).unwrap_err(), Error::CorruptSnapshot(_)));

    // decodes, but the version is from the future
    std::fs::write(&snapshot, "{\"version\": 99}\n").unwrap();
    assert!(matches!(build_resume(2).unwrap_err(), Error::CorruptSnapshot(_)));

    // a valid snapshot taken with a different shard count
    std::fs::write(&snapshot, &good).unwrap();
    assert!(matches!(build_resume(3).unwrap_err(), Error::CorruptSnapshot(_)));

    // mid-log damage in a cold segment file
    std::fs::write(
        dir.join("shard-0.cold.jsonl"),
        "garbage\n{\"op\":\"del\",\"tokens\":[1]}\n",
    )
    .unwrap();
    assert!(matches!(build_resume(2).unwrap_err(), Error::CorruptSnapshot(_)));

    // and the undamaged snapshot still resumes
    std::fs::write(dir.join("shard-0.cold.jsonl"), "").unwrap();
    build_resume(2).expect("clean state resumes");
    let _ = std::fs::remove_dir_all(&dir);
}
