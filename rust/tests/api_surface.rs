//! Integration: the `contextpilot::api` facade itself.
//!
//! Builder validation (every rejected knob is a typed
//! [`Error::InvalidConfig`], never a panic), the session/ticket request
//! lifecycle (duplicate submits, cross-session interleaving, unknown
//! sessions), and the facade's core equivalence contract: the
//! `serve_batch`/`serve_one` shims over the ticket path reproduce the
//! engine-room results bit for bit.

use std::sync::Arc;

use contextpilot::api::{Error, PlacementKind, Server};
use contextpilot::corpus::{Corpus, CorpusConfig};
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::corpus_for;
use contextpilot::tokenizer::Tokenizer;
use contextpilot::types::{BlockId, QueryId, Request, RequestId, SessionId};
use contextpilot::util::prop::reuse_fingerprint;
use contextpilot::workload::{hybrid, Dataset};

fn small_corpus() -> Corpus {
    Corpus::generate(
        &CorpusConfig {
            n_docs: 20,
            ..Default::default()
        },
        &Tokenizer::default(),
    )
}

fn req(id: u64, session: u32, ids: &[u32]) -> Request {
    Request {
        id: RequestId(id),
        session: SessionId(session),
        turn: 0,
        context: ids.iter().map(|&i| BlockId(i)).collect(),
        query: QueryId(id),
    }
}

fn invalid_msg(r: Result<Server, Error>) -> String {
    match r {
        Err(Error::InvalidConfig(msg)) => msg,
        Err(other) => panic!("expected InvalidConfig, got {other:?}"),
        Ok(_) => panic!("expected InvalidConfig, got a server"),
    }
}

// ---- builder validation ----------------------------------------------------

#[test]
fn zero_shards_is_invalid_config() {
    let msg = invalid_msg(
        Server::builder(ModelSku::Qwen3_4B)
            .shards(0)
            .corpus(small_corpus())
            .build(),
    );
    assert!(msg.contains("shards"), "got: {msg}");
}

#[test]
fn zero_workers_is_invalid_config() {
    let msg = invalid_msg(
        Server::builder(ModelSku::Qwen3_4B)
            .workers(0)
            .corpus(small_corpus())
            .build(),
    );
    assert!(msg.contains("workers"), "got: {msg}");
}

#[test]
fn zero_capacity_is_invalid_config() {
    let msg = invalid_msg(
        Server::builder(ModelSku::Qwen3_4B)
            .capacity(0)
            .corpus(small_corpus())
            .build(),
    );
    assert!(msg.contains("capacity"), "got: {msg}");
}

#[test]
fn zero_prefill_chunk_is_invalid_config() {
    let msg = invalid_msg(
        Server::builder(ModelSku::Qwen3_4B)
            .prefill_chunk(0)
            .corpus(small_corpus())
            .build(),
    );
    assert!(msg.contains("chunk"), "got: {msg}");
}

#[test]
fn missing_corpus_is_invalid_config() {
    let msg = invalid_msg(Server::builder(ModelSku::Qwen3_4B).build());
    assert!(msg.contains("corpus"), "got: {msg}");
}

#[test]
fn malformed_tier_specs_are_invalid_config() {
    for bad in [
        "dram=10",       // hbm required
        "hbm=0",         // hbm must be > 0
        "hbm=x",         // not a number
        "vram=10,hbm=1", // unknown tier
        "hbm",           // missing '='
    ] {
        let msg = invalid_msg(
            Server::builder(ModelSku::Qwen3_4B)
                .tiers(bad)
                .corpus(small_corpus())
                .build(),
        );
        assert!(!msg.is_empty(), "spec '{bad}' must explain itself");
    }
    // duplicate keys are ambiguous, not last-wins ("hbm=64k,hbm=1" used to
    // silently mean hbm=1)
    for dup in ["hbm=64k,hbm=1", "hbm=1,dram=2,dram=3", "hbm=1,ssd=2,ssd=2"] {
        let msg = invalid_msg(
            Server::builder(ModelSku::Qwen3_4B)
                .tiers(dup)
                .corpus(small_corpus())
                .build(),
        );
        assert!(msg.contains("more than once"), "spec '{dup}': {msg}");
    }
    // the k/m-suffixed shape from the docs parses
    let server = Server::builder(ModelSku::Qwen3_4B)
        .tiers("hbm=64k,dram=256k")
        .corpus(small_corpus())
        .build()
        .expect("suffixed tier spec is valid");
    assert_eq!(server.config().capacity_tokens, 64_000);
    assert_eq!(
        server.config().tiers.as_ref().map(|t| t.dram_tokens),
        Some(256_000)
    );
}

#[test]
fn placement_parse_errors_are_invalid_config() {
    assert!(matches!(
        PlacementKind::parse("nearest"),
        Err(Error::InvalidConfig(_))
    ));
}

// ---- session / ticket lifecycle -------------------------------------------

fn small_server() -> Server {
    Server::builder(ModelSku::Qwen3_4B)
        .shards(2)
        .workers(2)
        .decode_tokens(8)
        .corpus(small_corpus())
        .build()
        .expect("test config is valid")
}

#[test]
fn duplicate_submit_is_a_typed_error_not_a_panic() {
    let server = small_server();
    let t = server.session(SessionId(1)).submit(req(1, 1, &[1, 2])).unwrap();
    t.wait().expect("first submit serves");
    // same id again — whether from the same or another session
    assert_eq!(
        server
            .session(SessionId(1))
            .submit(req(1, 1, &[1, 2]))
            .unwrap_err(),
        Error::DuplicateRequest(RequestId(1))
    );
    assert_eq!(
        server
            .session(SessionId(2))
            .submit(req(1, 2, &[3]))
            .unwrap_err(),
        Error::DuplicateRequest(RequestId(1))
    );
}

#[test]
fn rejected_batch_admits_nothing() {
    // a duplicate id anywhere in the slice must leave the wave untouched:
    // no half-queued prefix served later, no ids burned in the ledger
    let server = small_server();
    let bad = vec![req(1, 1, &[1]), req(2, 2, &[2]), req(2, 3, &[3])];
    assert_eq!(
        server.serve_batch(&bad).unwrap_err(),
        Error::DuplicateRequest(RequestId(2))
    );
    assert_eq!(server.flush().expect("flush"), 0, "nothing was queued");
    let (m, _) = server.metrics().expect("metrics");
    assert_eq!(m.len(), 0);
    // the corrected batch — reusing id 1 — now succeeds
    let good = vec![req(1, 1, &[1]), req(2, 2, &[2]), req(3, 3, &[3])];
    assert_eq!(server.serve_batch(&good).expect("serve").len(), 3);
}

#[test]
fn unknown_session_is_a_typed_error() {
    let server = small_server();
    assert_eq!(
        server.session_shard(SessionId(77)).unwrap_err(),
        Error::UnknownSession(SessionId(77))
    );
    assert_eq!(
        server.session(SessionId(77)).shard().unwrap_err(),
        Error::UnknownSession(SessionId(77))
    );
    // a predicted shard exists even before placement
    assert!(server.predicted_shard(SessionId(77)).unwrap() < server.n_shards());
    // after serving, the pin is known and within range
    server.serve_one(&req(1, 77, &[1])).expect("serve");
    let pinned = server.session_shard(SessionId(77)).expect("placed now");
    assert!(pinned < server.n_shards());
    assert_eq!(pinned, server.predicted_shard(SessionId(77)).unwrap());
}

#[test]
fn cross_session_submissions_share_one_wave() {
    let server = small_server();
    let tickets: Vec<_> = (0..6)
        .map(|i| {
            server
                .session(SessionId(i as u32))
                .submit(req(i, i as u32, &[1, 2, (i % 3) as u32 + 3]))
                .expect("submit")
        })
        .collect();
    // one flush serves all six pending submissions as one admission wave
    assert_eq!(server.flush().expect("flush"), 6);
    for (i, t) in tickets.into_iter().enumerate() {
        let r = t
            .try_result()
            .expect("wave served")
            .expect("already resolved by the flush");
        assert_eq!(r.request.id, RequestId(i as u64));
        // (wait() would also return instantly here — the fast path)
    }
    let (m, _) = server.metrics().expect("metrics");
    assert_eq!(m.len(), 6);
}

#[test]
fn remaining_error_variants_display_and_box() {
    // ShardPoisoned and EngineFailure cannot be provoked through the
    // public surface without crashing a worker; pin their Display shape
    // and std::error::Error conformance here so the catalogue is covered.
    let poisoned = Error::ShardPoisoned("shard");
    assert!(poisoned.to_string().contains("panicked"));
    let failed = Error::EngineFailure("lost request".into());
    assert!(failed.to_string().contains("lost request"));
    let boxed: Box<dyn std::error::Error> = Box::new(failed);
    assert!(boxed.to_string().starts_with("engine failure"));
    // the durable-path variants (provoked end-to-end in tests/recovery.rs)
    let io = Error::Storage("disk on fire".into());
    assert!(io.to_string().starts_with("storage failure"));
    assert!(io.to_string().contains("disk on fire"));
    let bad = Error::CorruptSnapshot("snapshot.json line 3".into());
    assert!(bad.to_string().starts_with("corrupt snapshot"));
    assert!(bad.to_string().contains("line 3"));
    // the backpressure variant (provoked end-to-end in tests/sched.rs):
    // callers match on the id to decide what to resubmit, so it must
    // survive Display round-trips too
    let shed = Error::Overloaded(RequestId(41));
    assert!(shed.to_string().starts_with("overloaded"));
    assert!(shed.to_string().contains("41"));
    assert!(shed.to_string().contains("backpressure"));
}

// ---- facade equivalence ----------------------------------------------------

#[test]
fn ticket_path_and_batch_shim_agree_bit_for_bit() {
    let w = hybrid(Dataset::MtRag, 12, 2, 6, 0xFACADE);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let build = || {
        Server::builder(ModelSku::Qwen3_4B)
            .shards(3)
            .workers(2)
            .capacity(40_000)
            .decode_tokens(8)
            .corpus(corpus.clone())
            .build()
            .expect("config is valid")
    };
    // path A: the serve_batch shim
    let a = build();
    let batch_served = a.serve_batch(&w.requests).expect("serve");
    // path B: explicit submit-all + flush + wait-all over the same wave
    let b = build();
    let tickets: Vec<_> = w
        .requests
        .iter()
        .map(|r| b.session(r.session).submit(r.clone()).expect("submit"))
        .collect();
    b.flush().expect("flush");
    let ticket_served: Vec<_> = tickets
        .into_iter()
        .map(|t| t.wait().expect("wait"))
        .collect();
    assert_eq!(
        reuse_fingerprint(&batch_served),
        reuse_fingerprint(&ticket_served),
        "the shim and the explicit ticket path must be the same code path"
    );
}
