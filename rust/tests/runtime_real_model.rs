//! Integration: the real PJRT runtime against built artifacts.
//! Requires `make artifacts` (skipped otherwise) and the `pjrt` cargo
//! feature (the whole file compiles out without it — the offline image
//! carries neither the `xla` nor the `anyhow` crate).

#![cfg(feature = "pjrt")]

use contextpilot::corpus::{Corpus, CorpusConfig};
use contextpilot::runtime::{RealEngine, TinyLmRuntime};
use contextpilot::tokenizer::Tokenizer;
use contextpilot::types::*;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("model_meta.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn load_and_prefill() {
    let dir = require_artifacts!();
    let rt = TinyLmRuntime::load(&dir).expect("load artifacts");
    assert_eq!(rt.platform(), "cpu");
    let tokens: Vec<u32> = (16..48u32).collect();
    let (logits, kv) = rt.prefill(&tokens, rt.empty_kv().unwrap()).unwrap();
    assert_eq!(logits.len(), rt.meta.vocab);
    assert!(logits.iter().all(|x| x.is_finite()));
    assert_eq!(kv.len, tokens.len());
}

#[test]
fn chunked_prefill_matches_monolithic() {
    let dir = require_artifacts!();
    let rt = TinyLmRuntime::load(&dir).expect("load artifacts");
    let tokens: Vec<u32> = (0..100).map(|i| 16 + (i * 37) % 1900).collect();
    // monolithic
    let (lg_full, kv_full) = rt.prefill(&tokens, rt.empty_kv().unwrap()).unwrap();
    // split: 64 then 36
    let (_, kv1) = rt.prefill(&tokens[..64], rt.empty_kv().unwrap()).unwrap();
    let (lg2, kv2) = rt.prefill(&tokens[64..], kv1).unwrap();
    assert_eq!(kv2.len, kv_full.len);
    let max_diff = lg_full
        .iter()
        .zip(&lg2)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-3, "chunked != monolithic: {max_diff}");
}

#[test]
fn decode_is_deterministic() {
    let dir = require_artifacts!();
    let rt = TinyLmRuntime::load(&dir).expect("load artifacts");
    let prompt: Vec<u32> = (16..32u32).collect();
    let run = || {
        let (lg, kv) = rt.prefill(&prompt, rt.empty_kv().unwrap()).unwrap();
        rt.decode(lg, kv, 8).unwrap().0
    };
    let a = run();
    let b = run();
    assert_eq!(a, b);
    assert_eq!(a.len(), 8);
    assert!(a.iter().all(|&t| (t as usize) < rt.meta.vocab));
}

#[test]
fn real_engine_kv_reuse_speeds_up_and_matches() {
    let dir = require_artifacts!();
    let rt = TinyLmRuntime::load(&dir).expect("load artifacts");
    let mut engine = RealEngine::new(rt, 1 << 20);
    let corpus = Corpus::generate(
        &CorpusConfig {
            n_docs: 12,
            lines_per_doc: 3,
            words_per_line: 6,
            ..Default::default()
        },
        &Tokenizer::new(2048),
    );
    let mk = |id: u64, ids: &[u32]| Request {
        id: RequestId(id),
        session: SessionId(id as u32),
        turn: 0,
        context: ids.iter().map(|&i| BlockId(i)).collect(),
        query: QueryId(id),
    };
    let r1 = mk(1, &[1, 2, 3]);
    let r2 = mk(2, &[1, 2, 4]); // shares the {1,2} prefix
    let (s1, _, ans1) = engine
        .serve(&r1, &Prompt::baseline(&r1), &corpus, 4)
        .unwrap();
    let (s2, _, _) = engine
        .serve(&r2, &Prompt::baseline(&r2), &corpus, 4)
        .unwrap();
    assert_eq!(s1.cached_tokens, 0);
    assert!(
        s2.cached_tokens > 0,
        "second request should reuse the real KV prefix"
    );
    assert_eq!(ans1.len(), 4);

    // identical prompt re-served: full cache hit, same answer
    let r3 = mk(3, &[1, 2, 3]);
    let (s3, _, ans3) = engine
        .serve(&r3, &Prompt::baseline(&mk(1, &[1, 2, 3])), &corpus, 4)
        .unwrap();
    assert_eq!(s3.cached_tokens, s3.prompt_tokens);
    assert_eq!(ans1, ans3, "KV reuse changed the model output");
    assert!(s3.ttft < s1.ttft, "full hit not faster: {} vs {}", s3.ttft, s1.ttft);
}
