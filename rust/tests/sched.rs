//! Integration: the continuous-batching scheduler (`serve::sched`)
//! through the facade's open-loop lifecycle — `Server::submit_at` →
//! `seal_arrivals` → `drain` → ticket waits. The contracts under test:
//!
//! 1. the flush barrier is gone: a short request admitted behind a long
//!    chunked prefill completes *before* the long request, instead of
//!    waiting for its wave to drain;
//! 2. scheduling is deterministic: per-request results — hit/miss AND
//!    the `queued_ttft` sojourn bit patterns — are identical across
//!    worker counts and across re-runs, because progress is a pure
//!    function of the (virtual-time) arrival sequence;
//! 3. SLO backpressure is part of that pure function: which arrivals a
//!    queue bound sheds (or delays) and which a deadline drops is exact,
//!    counted, and replayable;
//! 4. scheduler lifecycle (`sched_started` / `sched_paused` /
//!    `sched_resumed` / `sched_drained`, `backpressure`) lands in the
//!    trace catalogue, worker-count invariant;
//! 5. the always-on registry keeps mirroring `RunMetrics` under
//!    continuous admission, including the `max_queue_depth` gauge;
//! 6. the wave path (`serve_batch`) still works on the same server —
//!    including while open-loop work sits frontier-gated (a wave behind
//!    a gated shard must complete, not deadlock), and context-aware
//!    placement stays bit-identical on the open-loop path (the
//!    scheduler quiesces before probe-reading placements).

use std::sync::Arc;

use contextpilot::api::{Error, ObsConfig, Server};
use contextpilot::corpus::{Corpus, CorpusConfig};
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::corpus_for;
use contextpilot::serve::OverloadPolicy;
use contextpilot::tokenizer::Tokenizer;
use contextpilot::types::{BlockId, QueryId, Request, RequestId, ServedRequest, SessionId};
use contextpilot::workload::{open_loop, Dataset, TimedWorkload};

fn req(id: u64, session: u32, blocks: &[u32]) -> Request {
    Request {
        id: RequestId(id),
        session: SessionId(session),
        turn: 0,
        context: blocks.iter().map(|&b| BlockId(b)).collect(),
        query: QueryId(id),
    }
}

/// Open-loop outcome per arrival, in arrival order: `Ok(served)` or the
/// shed request's id. Any other ticket error is a test failure.
fn run_open_loop(server: &Server, tw: &TimedWorkload) -> Vec<Result<ServedRequest, u64>> {
    let tickets: Vec<_> = tw
        .workload
        .requests
        .iter()
        .zip(&tw.arrivals)
        .map(|(r, &at)| server.submit_at(r.clone(), at).expect("submit arrival"))
        .collect();
    server.seal_arrivals().expect("seal");
    server.drain().expect("drain");
    tickets
        .into_iter()
        .map(|t| match t.wait() {
            Ok(s) => Ok(s),
            Err(Error::Overloaded(id)) => Err(id.0),
            Err(e) => panic!("open-loop ticket failed: {e}"),
        })
        .collect()
}

/// Deterministic outcome signature: reuse results plus the sojourn bits.
fn signature(outcomes: &[Result<ServedRequest, u64>]) -> Vec<(u64, usize, usize, u64, bool)> {
    outcomes
        .iter()
        .map(|o| match o {
            Ok(s) => (
                s.request.id.0,
                s.prompt_tokens,
                s.cached_tokens,
                s.queued_ttft.to_bits(),
                true,
            ),
            Err(id) => (*id, 0, 0, 0, false),
        })
        .collect()
}

fn counter(counters: &[(&'static str, u64)], name: &str) -> u64 {
    counters
        .iter()
        .find(|(n, _)| *n == name)
        .map(|&(_, v)| v)
        .unwrap_or_else(|| panic!("no counter named {name}"))
}

/// The flush-barrier removal, pinned. One shard, chunked prefill: a long
/// request arrives at t=0, a short one a millisecond later. Under the old
/// wave barrier the short request could not complete before the wave —
/// i.e. before the long prefill — drained. Under the scheduler loops the
/// short request is admitted mid-prefill, its chunks interleave with the
/// long request's, and it finishes first.
#[test]
fn short_request_overtakes_long_prefill() {
    let corpus = Corpus::generate(
        &CorpusConfig {
            n_docs: 24,
            ..Default::default()
        },
        &Tokenizer::default(),
    );
    let server = Server::builder(ModelSku::Qwen3_4B)
        .shards(1)
        .workers(1)
        .capacity(1 << 20)
        .prefill_chunk(256)
        .corpus(corpus)
        .build()
        .expect("config is valid");
    let long = req(1, 1, &(1u32..=16).collect::<Vec<_>>());
    let short = req(2, 2, &[20]);
    let t_long = server.submit_at(long, 0.0).expect("submit long");
    let t_short = server.submit_at(short, 0.001).expect("submit short");
    server.seal_arrivals().expect("seal");
    server.drain().expect("drain");
    let long = t_long.wait().expect("long serves");
    let short = t_short.wait().expect("short serves");
    assert!(
        long.prefill_chunks >= 2,
        "long prefill must be chunked for interleaving to mean anything \
         (got {} chunks)",
        long.prefill_chunks
    );
    let done_long = 0.0 + long.queued_ttft;
    let done_short = 0.001 + short.queued_ttft;
    assert!(
        done_short < done_long,
        "short request ({done_short:.4}s) must overtake the long prefill \
         ({done_long:.4}s): the flush barrier is gone"
    );
    assert!(
        short.queued_ttft < long.queued_ttft,
        "short sojourn must undercut the long one"
    );
}

#[test]
fn open_loop_results_are_bit_identical_across_worker_counts() {
    let tw = open_loop(Dataset::MtRag, 32, 8, 16.0, 0x5EED);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |workers: usize| {
        let server = Server::builder(ModelSku::Qwen3_4B)
            .shards(2)
            .workers(workers)
            .capacity(1 << 20)
            .prefill_chunk(1024)
            .corpus(corpus.clone())
            .build()
            .expect("config is valid");
        let sig = signature(&run_open_loop(&server, &tw));
        (sig, server.counters())
    };
    let (base, counters) = run(1);
    assert_eq!(base.len(), tw.len());
    assert!(base.iter().all(|&(.., ok)| ok), "unbounded run sheds nothing");
    assert!(
        base.iter().any(|&(_, _, cached, _, _)| cached > 0),
        "workload should produce cache hits"
    );
    for workers in [2usize, 4, 8] {
        assert_eq!(
            run(workers),
            (base.clone(), counters.clone()),
            "workers={workers} changed open-loop results or counters"
        );
    }
    // and the whole thing replays bit-identically
    assert_eq!(run(4), run(4), "re-run diverged");
}

#[test]
fn queue_bound_shed_is_deterministic_and_exact() {
    // 200 offered QPS into one shard with a queue bound of 1: heavy
    // overload, most arrivals shed. Which ones is a pure function of the
    // arrival sequence.
    let tw = open_loop(Dataset::MtRag, 24, 6, 200.0, 0x0C0FFEE);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |workers: usize| {
        let server = Server::builder(ModelSku::Qwen3_4B)
            .shards(1)
            .workers(workers)
            .capacity(1 << 20)
            .prefill_chunk(1024)
            .queue_bound(1)
            .overload(OverloadPolicy::Shed)
            .corpus(corpus.clone())
            .build()
            .expect("config is valid");
        let outcomes = run_open_loop(&server, &tw);
        let shed: Vec<u64> = outcomes.iter().filter_map(|o| o.as_ref().err().copied()).collect();
        let c = server.counters();
        assert_eq!(
            counter(&c, "backpressure_shed"),
            shed.len() as u64,
            "shed counter must equal Overloaded tickets"
        );
        assert_eq!(counter(&c, "backpressure_delayed"), 0);
        (signature(&outcomes), shed)
    };
    let (base, shed) = run(1);
    assert!(!shed.is_empty(), "overload must shed at this rate");
    assert!(
        shed.len() < tw.len(),
        "the shard must still serve something"
    );
    for workers in [2usize, 4] {
        assert_eq!(run(workers), (base.clone(), shed.clone()), "workers={workers}");
    }
    assert_eq!(run(1), (base, shed), "re-run diverged");
}

#[test]
fn delay_policy_serves_everything() {
    let tw = open_loop(Dataset::MtRag, 24, 6, 200.0, 0x0C0FFEE);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let server = Server::builder(ModelSku::Qwen3_4B)
        .shards(1)
        .workers(2)
        .capacity(1 << 20)
        .prefill_chunk(1024)
        .queue_bound(1)
        .overload(OverloadPolicy::Delay)
        .corpus(corpus.clone())
        .build()
        .expect("config is valid");
    let outcomes = run_open_loop(&server, &tw);
    assert!(
        outcomes.iter().all(|o| o.is_ok()),
        "delay policy must never shed on queue depth"
    );
    let c = server.counters();
    assert_eq!(counter(&c, "backpressure_shed"), 0);
    assert!(
        counter(&c, "backpressure_delayed") >= 1,
        "this overload must have delayed admissions"
    );
    // the price of delay: sojourns grow with queue position
    let last = outcomes.last().unwrap().as_ref().unwrap();
    let first = outcomes.first().unwrap().as_ref().unwrap();
    assert!(
        last.queued_ttft > first.queued_ttft,
        "overloaded tail must wait longer than the head"
    );
}

#[test]
fn deadline_misses_are_shed_whatever_the_policy() {
    let tw = open_loop(Dataset::MtRag, 24, 6, 200.0, 0x0C0FFEE);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    // Delay policy on purpose: deadline misses shed regardless.
    let server = Server::builder(ModelSku::Qwen3_4B)
        .shards(1)
        .workers(1)
        .capacity(1 << 20)
        .prefill_chunk(1024)
        .deadline(0.001)
        .overload(OverloadPolicy::Delay)
        .corpus(corpus.clone())
        .build()
        .expect("config is valid");
    let outcomes = run_open_loop(&server, &tw);
    let shed = outcomes.iter().filter(|o| o.is_err()).count();
    let served = outcomes.iter().filter(|o| o.is_ok()).count();
    assert!(shed >= 1, "a 1ms admission deadline must shed under overload");
    assert!(served >= 1, "an idle shard admits at zero lateness");
    let c = server.counters();
    assert_eq!(counter(&c, "backpressure_shed"), shed as u64);
}

#[test]
fn scheduler_lifecycle_is_traced_and_worker_invariant() {
    let tw = open_loop(Dataset::MtRag, 16, 6, 100.0, 0xBEE);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |workers: usize| {
        let server = Server::builder(ModelSku::Qwen3_4B)
            .shards(2)
            .workers(workers)
            .capacity(1 << 20)
            .prefill_chunk(1024)
            .queue_bound(1)
            .overload(OverloadPolicy::Shed)
            .observability(ObsConfig::tracing())
            .corpus(corpus.clone())
            .build()
            .expect("config is valid");
        // Scripted while nothing is in flight, so the pause/resume stamps
        // sit at deterministic points of the virtual clocks.
        server.pause().expect("pause");
        server.resume().expect("resume");
        run_open_loop(&server, &tw);
        let mut events = server.trace_events().expect("trace");
        events.sort_by_key(|e| (e.shard, e.seq));
        events
    };
    let base = run(1);
    for name in [
        "sched_started",
        "sched_paused",
        "sched_resumed",
        "sched_drained",
        "backpressure",
        "admitted",
        "placed",
        "queued",
        "prefill_chunk",
        "resolved",
    ] {
        assert!(
            base.iter().any(|e| e.kind.name() == name),
            "missing lifecycle event {name}"
        );
    }
    for workers in [2usize, 4] {
        assert_eq!(run(workers), base, "workers={workers} changed the trace");
    }
}

/// Satellite pin: the always-on registry keeps mirroring `RunMetrics`
/// exactly under continuous admission — no wave flush ever reconciles
/// them, so every open-loop completion must count at source.
#[test]
fn registry_mirrors_metrics_under_continuous_admission() {
    let tw = open_loop(Dataset::MtRag, 32, 8, 16.0, 0x5EED);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let server = Server::builder(ModelSku::Qwen3_4B)
        .shards(2)
        .workers(2)
        .capacity(1 << 20)
        .prefill_chunk(1024)
        .corpus(corpus.clone())
        .build()
        .expect("config is valid");
    run_open_loop(&server, &tw);
    let (m, per_shard) = server.metrics().expect("metrics");
    let c = server.counters();
    assert_eq!(counter(&c, "requests_served"), m.len() as u64);
    assert_eq!(counter(&c, "prompt_tokens"), m.total_prompt_tokens);
    assert_eq!(counter(&c, "cached_tokens"), m.total_cached_tokens);
    assert_eq!(counter(&c, "hot_hit_tokens"), m.total_hot_hit_tokens);
    assert_eq!(counter(&c, "warm_hit_tokens"), m.total_warm_hit_tokens);
    assert_eq!(counter(&c, "cold_hit_tokens"), m.total_cold_hit_tokens);
    assert_eq!(counter(&c, "prefill_chunks"), m.total_prefill_chunks);
    let max_depth = per_shard.iter().map(|s| s.max_queue_depth).max();
    assert_eq!(counter(&c, "max_queue_depth"), max_depth.unwrap_or(0) as u64);
    assert!(
        counter(&c, "max_queue_depth") >= 1,
        "continuous admission must register queue depth"
    );
    assert_eq!(counter(&c, "requests_served"), tw.len() as u64);
}

/// Deadlock regression: a wave submitted while an *unsealed* open-loop
/// request sits frontier-gated (clock == frontier, chunks not yet
/// runnable) must complete without anyone advancing the frontier — the
/// submitting thread is the very thread that would. The scheduler used
/// to refuse to claim waves while any open-loop request was mid-prefill,
/// deadlocking this exact single-threaded sequence forever.
#[test]
fn wave_completes_behind_frontier_gated_open_loop_work() {
    let corpus = Corpus::generate(
        &CorpusConfig {
            n_docs: 24,
            ..Default::default()
        },
        &Tokenizer::default(),
    );
    let server = Server::builder(ModelSku::Qwen3_4B)
        .shards(1)
        .workers(1)
        .capacity(1 << 20)
        .prefill_chunk(256)
        .corpus(corpus)
        .build()
        .expect("config is valid");
    // Admitted at t=0 and then gated: its chunks may not run while
    // clock == frontier and arrivals are unsealed.
    let gated = server
        .submit_at(req(1, 1, &(1u32..=16).collect::<Vec<_>>()), 0.0)
        .expect("submit gated arrival");
    server.drain().expect("drain parks at the frontier");
    // One shard, so the wave necessarily queues behind the gated work.
    let wave = server
        .serve_batch(&[req(2, 2, &[20])])
        .expect("wave must serve while the shard is frontier-gated");
    assert_eq!(wave.len(), 1);
    // The gated arrival is untouched by the wave: seal and finish it.
    server.seal_arrivals().expect("seal");
    let served = gated.wait().expect("gated arrival serves after seal");
    assert!(served.prefill_chunks >= 1);
    server.drain().expect("drain runs dry");
    let (m, _) = server.metrics().expect("metrics");
    assert_eq!(m.len(), 2, "both paths landed in RunMetrics");
}

/// Context-aware placement stays deterministic on the open-loop path:
/// the shard each session lands on — decided from published probe
/// snapshots — and the full outcome signature are identical across
/// worker counts and across re-runs. (Regression: placement used to
/// read probe snapshots wherever the loops happened to be in wall
/// time; the scheduler now quiesces before each unpinned placement.)
#[test]
fn context_aware_open_loop_placement_is_deterministic() {
    use contextpilot::api::PlacementKind;
    let tw = open_loop(Dataset::MtRag, 32, 8, 16.0, 0x5EED);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |workers: usize| {
        let server = Server::builder(ModelSku::Qwen3_4B)
            .shards(4)
            .workers(workers)
            .capacity(1 << 20)
            .prefill_chunk(1024)
            .placement(PlacementKind::ContextAware)
            .corpus(corpus.clone())
            .build()
            .expect("config is valid");
        let sig = signature(&run_open_loop(&server, &tw));
        // pin the shard choices themselves, not just the outcomes
        let shards: Vec<usize> = tw
            .workload
            .requests
            .iter()
            .map(|r| server.session_shard(r.session).expect("session placed"))
            .collect();
        (sig, shards, server.counters())
    };
    let base = run(1);
    assert!(
        base.0.iter().any(|&(_, _, cached, _, _)| cached > 0),
        "workload should produce cache hits"
    );
    for workers in [2usize, 4] {
        assert_eq!(
            run(workers),
            base,
            "workers={workers} changed context-aware open-loop placement"
        );
    }
    assert_eq!(run(2), run(2), "re-run diverged");
}

#[test]
fn wave_path_composes_with_open_loop() {
    let tw = open_loop(Dataset::MtRag, 16, 6, 16.0, 0xAB);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let server = Server::builder(ModelSku::Qwen3_4B)
        .shards(2)
        .workers(2)
        .capacity(1 << 20)
        .prefill_chunk(1024)
        .corpus(corpus.clone())
        .build()
        .expect("config is valid");
    // a wave before any open-loop traffic…
    let wave = server
        .serve_batch(&[req(9001, 901, &[1, 2, 3])])
        .expect("wave serves");
    assert_eq!(wave.len(), 1);
    // …then the open-loop run…
    let outcomes = run_open_loop(&server, &tw);
    assert!(outcomes.iter().all(|o| o.is_ok()));
    // …and waves still flow after the arrival process is sealed.
    let after = server
        .serve_batch(&[req(9002, 901, &[1, 2, 3])])
        .expect("wave serves after seal");
    assert_eq!(after.len(), 1);
    assert!(
        after[0].cached_tokens > 0,
        "the sealed scheduler still serves reuse from shard state"
    );
    let (m, _) = server.metrics().expect("metrics");
    assert_eq!(m.len(), tw.len() + 2, "every path lands in RunMetrics");
}
