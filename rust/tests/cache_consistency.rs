//! Integration: radix-cache invariants under randomized operation
//! sequences (failure-injection style).

use contextpilot::cache::RadixCache;
use contextpilot::types::RequestId;
use contextpilot::util::prng::Rng;
use contextpilot::util::prop::{check, Config};

#[test]
fn random_op_sequences_preserve_invariants() {
    check(
        "radix cache fuzz",
        Config {
            cases: 64,
            base_seed: 0x0DD5,
            max_size: 200,
        },
        |rng: &mut Rng, size| {
            let cap = rng.range(8, 512);
            let mut cache: RadixCache<u32> = RadixCache::new(cap);
            let mut locked_paths = Vec::new();
            for op in 0..size {
                match rng.below(6) {
                    0 | 1 => {
                        let len = rng.range(1, 24);
                        let key: Vec<u32> = (0..len).map(|_| rng.below(16) as u32).collect();
                        cache.insert(&key, RequestId(op as u64));
                    }
                    2 => {
                        let len = rng.range(1, 24);
                        let key: Vec<u32> = (0..len).map(|_| rng.below(16) as u32).collect();
                        let m = cache.match_prefix(&key);
                        if m.len > 0 && rng.chance(0.3) && locked_paths.len() < 4 {
                            cache.lock_path(&m.path);
                            locked_paths.push(m.path);
                        }
                    }
                    3 => {
                        cache.evict_tokens(rng.range(1, 64));
                    }
                    4 => {
                        if let Some(p) = locked_paths.pop() {
                            cache.unlock_path(&p);
                        }
                    }
                    _ => {
                        let len = rng.range(1, 16);
                        let key: Vec<u32> = (0..len).map(|_| rng.below(16) as u32).collect();
                        cache.set_payload(&key, RequestId(9_000 + op as u64), op as u32);
                    }
                }
                if let Err(e) = cache.check_invariants_ignoring_capacity() {
                    return Err(format!("after op {op}: {e}"));
                }
            }
            for p in locked_paths.drain(..) {
                cache.unlock_path(&p);
            }
            cache.evict_tokens(usize::MAX / 2);
            cache
                .check_invariants_ignoring_capacity()
                .map_err(|e| format!("final: {e}"))
        },
    );
}

#[test]
fn match_result_is_true_prefix() {
    check(
        "match is prefix",
        Config {
            cases: 128,
            base_seed: 0xF1E,
            max_size: 64,
        },
        |rng: &mut Rng, size| {
            let mut cache: RadixCache<()> = RadixCache::new(1 << 16);
            let mut inserted: Vec<Vec<u32>> = Vec::new();
            for i in 0..size.max(2) {
                let len = rng.range(1, 32);
                let key: Vec<u32> = (0..len).map(|_| rng.below(8) as u32).collect();
                cache.insert(&key, RequestId(i as u64));
                inserted.push(key);
            }
            // probe with mutated keys
            for _ in 0..8 {
                let mut probe = rng.choice(&inserted).clone();
                if !probe.is_empty() && rng.chance(0.7) {
                    let idx = rng.below(probe.len());
                    probe[idx] = rng.below(8) as u32;
                }
                let m = cache.match_prefix(&probe);
                if m.len > probe.len() {
                    return Err("matched beyond key".to_string());
                }
                // the matched prefix must literally exist among inserted keys
                let pre = &probe[..m.len];
                if m.len > 0
                    && !inserted
                        .iter()
                        .any(|k| k.len() >= m.len && &k[..m.len] == pre)
                {
                    return Err(format!("matched prefix {pre:?} never inserted"));
                }
            }
            Ok(())
        },
    );
}
