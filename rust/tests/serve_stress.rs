//! Integration: the concurrent sharded serving stack behind
//! `contextpilot::api` (the engine room itself is crate-private; every
//! assertion here runs through the facade's session/ticket lifecycle,
//! which is exactly the point — the facade must preserve the engine
//! room's contracts bit for bit).
//!
//! Determinism contract under test: shard state is session-local and
//! per-shard queues preserve arrival order, so (1) hit/miss results are
//! identical for any worker count, (2) they equal a hand-rolled
//! single-shard pipeline fed the same queue, and (3) concurrent streaming
//! callers see the same results as a sequential run. Plus the §5/§6
//! safety properties under concurrency: alignment preserves the block
//! multiset, and de-duplication is idempotent.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use contextpilot::api::{Server, ServerBuilder};
use contextpilot::cache::TierConfig;
use contextpilot::corpus::Corpus;
use contextpilot::dedup::{dedup_context, DedupConfig};
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::engine::sim::{ReusePolicy, SimEngine};
use contextpilot::experiments::corpus_for;
use contextpilot::index::tree::ContextIndex;
use contextpilot::pilot::{ContextPilot, PilotConfig};
use contextpilot::quality::{ModelEra, QualityModel};
use contextpilot::serve::{shard_of, ServeConfig};
use contextpilot::types::{Request, RequestId, Segment, ServedRequest, SessionId};
use contextpilot::util::prng::Rng;
use contextpilot::util::prop::{
    check, gen_context, gen_requests, reuse_fingerprint, CaseResult, Config,
};
use contextpilot::workload::{hybrid, Dataset};

fn serve_cfg(shards: usize, workers: usize) -> ServeConfig {
    let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
    cfg.n_shards = shards;
    cfg.n_workers = workers;
    cfg.capacity_tokens = 40_000;
    cfg.decode_tokens = 8;
    cfg
}

/// Facade server over the simulated backend for a preassembled config.
fn server(cfg: ServeConfig, corpus: &Arc<Corpus>) -> Server {
    ServerBuilder::from_config(cfg)
        .corpus(corpus.clone())
        .build()
        .expect("test serve config is valid")
}

/// (request id, prompt tokens, cached tokens) — the hit/miss fingerprint.
fn fingerprint(served: &[ServedRequest]) -> Vec<(u64, usize, usize)> {
    served
        .iter()
        .map(|s| (s.request.id.0, s.prompt_tokens, s.cached_tokens))
        .collect()
}

#[test]
fn worker_count_does_not_change_results() {
    let w = hybrid(Dataset::MtRag, 24, 3, 8, 0x57E55);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |workers: usize| {
        let server = server(serve_cfg(6, workers), &corpus);
        fingerprint(&server.serve_batch(&w.requests).expect("serve"))
    };
    let base = run(1);
    assert_eq!(base.len(), w.requests.len());
    assert!(
        base.iter().any(|&(_, _, cached)| cached > 0),
        "workload should produce cache hits"
    );
    for workers in [2usize, 4, 8] {
        assert_eq!(run(workers), base, "workers={workers} changed hit/miss results");
    }
}

#[test]
fn sharded_cache_matches_single_shard_ground_truth() {
    // 4 worker threads vs a hand-rolled unsharded pipeline per shard: the
    // sharded cache must never return a prefix length the single-shard
    // ground truth does not.
    let n_shards = 4;
    let w = hybrid(Dataset::MtRag, 20, 3, 8, 0x6D7);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let server = server(serve_cfg(n_shards, 4), &corpus);
    let served = server.serve_batch(&w.requests).expect("serve");
    let mut compared = 0usize;
    for shard in 0..n_shards {
        let mine: Vec<Request> = w
            .requests
            .iter()
            .filter(|r| shard_of(r.session, n_shards) == shard)
            .cloned()
            .collect();
        if mine.is_empty() {
            continue;
        }
        let mut pilot = ContextPilot::new(PilotConfig::default());
        let mut eng = SimEngine::new(
            ModelSku::Qwen3_4B.profile(),
            ReusePolicy::RadixPrefix,
            40_000,
        );
        let qm = QualityModel::new(ModelEra::Modern, false);
        for o in pilot.process_batch(&mine, &corpus) {
            let (truth, evicted) = eng.serve(&o.request, &o.prompt, &corpus, &qm, 8);
            pilot.on_evict(&evicted);
            let got = served
                .iter()
                .find(|s| s.request.id == truth.request.id)
                .expect("request served");
            assert_eq!(
                got.cached_tokens, truth.cached_tokens,
                "cached prefix mismatch for {:?}",
                truth.request.id
            );
            assert_eq!(got.prompt_tokens, truth.prompt_tokens);
            compared += 1;
        }
    }
    assert_eq!(compared, w.requests.len());
}

#[test]
fn concurrent_streaming_matches_sequential() {
    // one OS thread per shard streams its own queue via serve_one; the
    // interleaving across shards is arbitrary, the results must not be.
    let n_shards = 4;
    let w = hybrid(Dataset::MtRag, 16, 3, 8, 0xC0C);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));

    let seq_server = server(serve_cfg(n_shards, 1), &corpus);
    let truth: Vec<ServedRequest> = w
        .requests
        .iter()
        .map(|r| seq_server.serve_one(r).expect("serve"))
        .collect();
    let truth_by_id: HashMap<u64, (usize, usize)> = truth
        .iter()
        .map(|s| (s.request.id.0, (s.prompt_tokens, s.cached_tokens)))
        .collect();

    let conc_server = server(serve_cfg(n_shards, 1), &corpus);
    let results: Vec<Mutex<Vec<ServedRequest>>> =
        (0..n_shards).map(|_| Mutex::new(Vec::new())).collect();
    std::thread::scope(|scope| {
        for shard in 0..n_shards {
            let conc_server = &conc_server;
            let w = &w;
            let slot = &results[shard];
            scope.spawn(move || {
                for r in w
                    .requests
                    .iter()
                    .filter(|r| shard_of(r.session, n_shards) == shard)
                {
                    slot.lock()
                        .unwrap()
                        .push(conc_server.serve_one(r).expect("serve"));
                }
            });
        }
    });

    let mut compared = 0usize;
    for slot in &results {
        for s in slot.lock().unwrap().iter() {
            assert_eq!(
                truth_by_id[&s.request.id.0],
                (s.prompt_tokens, s.cached_tokens),
                "request {:?} diverged under concurrency",
                s.request.id
            );
            compared += 1;
        }
    }
    assert_eq!(compared, w.requests.len());
}

#[test]
fn shard_metrics_aggregate_consistently() {
    let w = hybrid(Dataset::MtRag, 24, 2, 8, 0x3E7);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let server = server(serve_cfg(5, 4), &corpus);
    let served = server.serve_batch(&w.requests).expect("serve");
    let (agg, per) = server.metrics().expect("metrics");
    assert_eq!(agg.len(), served.len());
    assert_eq!(per.iter().map(|s| s.served).sum::<usize>(), served.len());
    for s in per.iter().filter(|s| s.served > 0) {
        assert!(s.p99_ttft >= s.p50_ttft, "shard {}", s.shard);
        assert!(s.max_queue_depth >= 1);
        assert!((0.0..=1.0).contains(&s.hit_ratio), "shard {}", s.shard);
        assert!(s.sessions >= 1);
    }
    let cached: usize = served.iter().map(|s| s.cached_tokens).sum();
    let total: usize = served.iter().map(|s| s.prompt_tokens).sum();
    assert!((agg.hit_ratio() - cached as f64 / total as f64).abs() < 1e-9);
}

#[test]
fn alignment_preserves_block_multiset_under_concurrent_access() {
    // 4 workers, alignment on, dedup off: every served prompt's full
    // blocks must be a permutation of the request's retrieval (so the
    // rendered token multiset of the context region is preserved).
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    check(
        "sharded alignment is a permutation",
        Config {
            cases: 12,
            base_seed: 0xA716,
            max_size: 48,
        },
        |rng: &mut Rng, size| {
            let reqs = gen_requests(rng, size.max(4), 12, 6, corpus.len());
            let mut cfg = serve_cfg(4, 4);
            cfg.pilot = Some(PilotConfig {
                dedup: None,
                ..PilotConfig::default()
            });
            let srv = server(cfg, &corpus);
            let served = srv.serve_batch(&reqs).expect("serve");
            for s in &served {
                let mut got = s.prompt.full_blocks();
                let mut want = s.request.context.clone();
                got.sort_unstable();
                want.sort_unstable();
                if got != want {
                    return Err(format!(
                        "request {:?}: prompt blocks {:?} != retrieval {:?}",
                        s.request.id, got, want
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dedup_is_idempotent() {
    // Once a context has been deduplicated against a session record,
    // re-deduplicating the identical context is a fixed point: every block
    // resolves to a location annotation and repeated passes agree exactly.
    let corpus = corpus_for(Dataset::MtRag);
    check(
        "dedup is idempotent",
        Config {
            cases: 64,
            base_seed: 0x1DE0,
            max_size: 10,
        },
        |rng: &mut Rng, size| {
            let context = gen_context(rng, size.max(1), corpus.len());
            if context.is_empty() {
                return CaseResult::Discard;
            }
            let mut ix = ContextIndex::new(0.001);
            let session = SessionId(rng.below(1000) as u32);
            let cfg = DedupConfig::default();
            let _first = dedup_context(&mut ix, session, &context, &corpus, &cfg);
            let (segs2, stats2) = dedup_context(&mut ix, session, &context, &corpus, &cfg);
            let (segs3, stats3) = dedup_context(&mut ix, session, &context, &corpus, &cfg);
            if segs2 != segs3 || stats2 != stats3 {
                return CaseResult::Fail("second and third pass diverged".to_string());
            }
            if !segs2.iter().all(|s| matches!(s, Segment::LocationRef(_))) {
                return CaseResult::Fail("seen blocks not fully annotated".to_string());
            }
            if stats2.blocks_deduped != context.len() {
                return CaseResult::Fail(format!(
                    "expected {} deduped blocks, got {}",
                    context.len(),
                    stats2.blocks_deduped
                ));
            }
            CaseResult::Pass
        },
    );
}

#[test]
fn tiered_accounting_is_worker_count_invariant() {
    // tight per-shard HBM under a multi-turn workload: session history is
    // evicted (demoted) between turns and promoted back on the next turn.
    // The per-request hot/warm/cold split and the aggregate tier totals
    // must be bit-identical for any worker count — the tier store is
    // shard-local state driven in shard serve order, like the radix cache.
    let w = hybrid(Dataset::MtRag, 24, 3, 8, 0x71E7);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |workers: usize| {
        let mut cfg = serve_cfg(6, workers);
        cfg.capacity_tokens = 1_500;
        cfg.tiers = Some(TierConfig::new(16_000, 64_000));
        let srv = server(cfg, &corpus);
        let served = srv.serve_batch(&w.requests).expect("serve");
        let fp = reuse_fingerprint(&served);
        let (m, per) = srv.metrics().expect("metrics");
        let residency: Vec<(usize, usize, u64, u64)> = per
            .iter()
            .map(|s| {
                (
                    s.dram_resident_tokens,
                    s.ssd_resident_tokens,
                    s.warm_hit_tokens,
                    s.cold_hit_tokens,
                )
            })
            .collect();
        (
            fp,
            m.total_hot_hit_tokens,
            m.total_warm_hit_tokens,
            m.total_cold_hit_tokens,
            m.total_cached_tokens,
            residency,
        )
    };
    let base = run(1);
    assert!(
        base.2 + base.3 > 0,
        "tight HBM must force warm/cold promotions"
    );
    assert_eq!(
        base.1 + base.2 + base.3,
        base.4,
        "hot+warm+cold must partition cached tokens"
    );
    for workers in [2usize, 4, 8] {
        assert_eq!(
            run(workers),
            base,
            "workers={workers} changed tier accounting"
        );
    }
}

#[test]
fn index_pruning_fires_on_final_discard_only() {
    // the eviction→index-prune→demotion chain, both ends:
    //  * roomy store: radix evictions demote, nothing is finally
    //    discarded, so the §4.1 prune callbacks NEVER fire — the pilot
    //    index must evolve exactly as it would with no evictions at all
    //    (same node count as a discard run with unbounded HBM);
    //  * tiny store: demotions overflow every tier, the discard ids
    //    surface through serve, and the index IS pruned.
    let w = hybrid(Dataset::MtRag, 10, 3, 8, 0xD15C);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let run = |capacity: usize, tiers: Option<TierConfig>| {
        let mut cfg = serve_cfg(1, 1);
        cfg.capacity_tokens = capacity;
        cfg.tiers = tiers;
        let srv = server(cfg, &corpus);
        srv.serve_batch(&w.requests).expect("serve");
        let (_, per) = srv.metrics().expect("metrics");
        (
            per[0].index_nodes,
            per[0].dram_resident_tokens + per[0].ssd_resident_tokens,
        )
    };
    let (unbounded_nodes, _) = run(1 << 24, None);
    // Always-admit: cost-aware admission would discard sub-50-token split
    // leaves (reload overhead beats recompute), firing prunes this test
    // needs provably absent
    let mut roomy_tiers = TierConfig::new(1 << 20, 1 << 20);
    roomy_tiers.admission = contextpilot::cache::AdmissionPolicy::Always;
    let (demote_nodes, demote_resident) = run(1_500, Some(roomy_tiers));
    assert!(
        demote_resident > 0,
        "tight HBM must actually demote (evictions occurred)"
    );
    assert_eq!(
        demote_nodes, unbounded_nodes,
        "no final discard -> the prune callback may never fire"
    );
    let (tiny_nodes, _) = run(1_500, Some(TierConfig::new(500, 500)));
    assert!(
        tiny_nodes < unbounded_nodes,
        "overflowing every tier must prune the index: {tiny_nodes} vs {unbounded_nodes}"
    );
}

#[test]
fn external_eviction_keeps_indices_consistent() {
    // serve, then evict every engine request id through the ServingEngine:
    // every shard's context index must prune down to its root.
    let w = hybrid(Dataset::MtRag, 18, 2, 8, 0xE71C);
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let srv = server(serve_cfg(4, 4), &corpus);
    let served = srv.serve_batch(&w.requests).expect("serve");
    assert_eq!(served.len(), w.requests.len());
    let ids: Vec<RequestId> = w.requests.iter().map(|r| r.id).collect();
    srv.on_evict(&ids).expect("evict");
    let (_, per) = srv.metrics().expect("metrics");
    for s in per {
        assert!(
            s.index_nodes <= 1,
            "shard {} index kept {} nodes after full eviction",
            s.shard,
            s.index_nodes
        );
    }
}
