//! Integration: the placement layer (`serve::placement`).
//!
//! Invariants under test, for every policy:
//!  * a session's turns all run on one shard (the first-turn pin);
//!  * placement — and therefore hit/miss results — is independent of
//!    `n_workers` (decisions happen at enqueue time, before workers run);
//!  * `SessionHash` reproduces the legacy `serve::shard_of` partition
//!    bit-for-bit;
//! plus the §7.2 acceptance claim: on the recurring-context workload,
//! `ContextAware` placement strictly beats `SessionHash` on cached
//! tokens (the same assertion `benches/bench_routing.rs` sweeps).

use std::collections::HashMap;
use std::sync::Arc;

use contextpilot::api::{Server, ServerBuilder};
use contextpilot::corpus::Corpus;
use contextpilot::engine::costmodel::ModelSku;
use contextpilot::experiments::{corpus_for, turn_waves};
use contextpilot::serve::{shard_of, PlacementKind, ServeConfig};
use contextpilot::types::{Request, SessionId};
use contextpilot::util::prng::Rng;
use contextpilot::util::prop::{
    check, gen_requests, reuse_fingerprint, Config, EngineCall, EngineLog, RecordingEngine,
};
use contextpilot::workload::{recurring, Dataset};

const POLICIES: [PlacementKind; 3] = [
    PlacementKind::SessionHash,
    PlacementKind::RoundRobin,
    PlacementKind::ContextAware,
];

fn cfg_with(placement: PlacementKind, shards: usize, workers: usize) -> ServeConfig {
    let mut cfg = ServeConfig::new(ModelSku::Qwen3_4B);
    cfg.n_shards = shards;
    cfg.n_workers = workers;
    cfg.capacity_tokens = 1 << 20; // roomy: isolate placement, not eviction
    cfg.decode_tokens = 8;
    cfg.placement = placement;
    cfg
}

/// Facade server over the simulated backend for a preassembled config.
fn sim_server(cfg: ServeConfig, corpus: &Arc<Corpus>) -> Server {
    ServerBuilder::from_config(cfg)
        .corpus(corpus.clone())
        .build()
        .expect("test serve config is valid")
}

/// Serve `reqs` through a recorded engine behind the facade and return
/// each request's shard.
fn shard_log(cfg: ServeConfig, reqs: &[Request], corpus: &Arc<Corpus>) -> Vec<EngineCall> {
    let log = EngineLog::default();
    let server = {
        let log = log.clone();
        let mut tag = 0usize;
        ServerBuilder::from_config(cfg)
            .corpus(corpus.clone())
            .build_with(move |c| {
                let e = RecordingEngine {
                    inner: ServeConfig::sim_engine(c),
                    shard_tag: tag,
                    log: log.clone(),
                };
                tag += 1;
                e
            })
            .expect("recorded serve config is valid")
    };
    for (i, j) in turn_waves(reqs) {
        server.serve_batch(&reqs[i..j]).expect("serve wave");
    }
    let calls = log.lock().expect("log poisoned");
    calls.clone()
}

#[test]
fn every_policy_keeps_a_sessions_turns_on_one_shard() {
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    for policy in POLICIES {
        check(
            &format!("{policy}: sessions stick to one shard"),
            Config {
                cases: 8,
                base_seed: 0x9AC3,
                max_size: 40,
            },
            |rng: &mut Rng, size| {
                let reqs = gen_requests(rng, size.max(6), 8, 5, corpus.len());
                let calls = shard_log(cfg_with(policy, 4, 2), &reqs, &corpus);
                if calls.len() != reqs.len() {
                    return Err(format!("{} served of {}", calls.len(), reqs.len()));
                }
                let session_of: HashMap<u64, u32> =
                    reqs.iter().map(|r| (r.id.0, r.session.0)).collect();
                let mut home: HashMap<u32, usize> = HashMap::new();
                for c in &calls {
                    let s = session_of[&c.request.0];
                    let shard = *home.entry(s).or_insert(c.shard);
                    if shard != c.shard {
                        return Err(format!(
                            "session {s} ran on shards {shard} and {} under {policy}",
                            c.shard
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}

#[test]
fn placement_is_independent_of_worker_count() {
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let w = recurring(Dataset::MtRag, 18, 3, 5, 6, 0x9C4);
    for policy in POLICIES {
        let run = |workers: usize| {
            let server = sim_server(cfg_with(policy, 4, workers), &corpus);
            let mut served = Vec::new();
            for (i, j) in turn_waves(&w.requests) {
                served.extend(server.serve_batch(&w.requests[i..j]).expect("serve wave"));
            }
            let (m, per) = server.metrics().expect("metrics");
            let placed: Vec<usize> = per.iter().map(|s| s.placed_sessions).collect();
            let by_shard: Vec<usize> = per.iter().map(|s| s.served).collect();
            (
                reuse_fingerprint(&served),
                placed,
                by_shard,
                m.total_affinity_hit_tokens,
            )
        };
        let base = run(1);
        for workers in [2usize, 4, 8] {
            assert_eq!(
                run(workers),
                base,
                "{policy}: workers={workers} changed placement or results"
            );
        }
    }
}

#[test]
fn session_hash_reproduces_shard_of_bit_for_bit() {
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    check(
        "session-hash placement == shard_of",
        Config {
            cases: 8,
            base_seed: 0x5EED5,
            max_size: 48,
        },
        |rng: &mut Rng, size| {
            let n_shards = 1 + rng.below(7);
            let reqs = gen_requests(rng, size.max(4), 10, 5, corpus.len());
            let calls = shard_log(
                cfg_with(PlacementKind::SessionHash, n_shards, 2),
                &reqs,
                &corpus,
            );
            let session_of: HashMap<u64, u32> =
                reqs.iter().map(|r| (r.id.0, r.session.0)).collect();
            for c in &calls {
                let session = SessionId(session_of[&c.request.0]);
                let want = shard_of(session, n_shards);
                if c.shard != want {
                    return Err(format!(
                        "request {:?} (session {session:?}) on shard {} != shard_of {want}",
                        c.request, c.shard
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn context_aware_decisions_are_bit_identical_and_probe_lock_free() {
    // the probe fast-path acceptance pin: ContextAware reads published
    // probe snapshots instead of locking shards, and that must change
    // nothing about the decisions — per-request shard assignments (in
    // engine-call order per shard) and the probe counters are byte-equal
    // across worker counts, probe work is non-zero, and the probe path
    // never takes a shard lock.
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let w = recurring(Dataset::MtRag, 18, 3, 5, 6, 0x9C4);
    let run = |workers: usize| {
        let log = shard_log(
            cfg_with(PlacementKind::ContextAware, 4, workers),
            &w.requests,
            &corpus,
        );
        let mut shard_of_req: Vec<(u64, usize)> =
            log.iter().map(|c| (c.request.0, c.shard)).collect();
        shard_of_req.sort_unstable();
        shard_of_req
    };
    let base = run(1);
    assert_eq!(base.len(), w.requests.len(), "every request must serve");
    for workers in [2usize, 4, 8] {
        assert_eq!(run(workers), base, "workers={workers} moved a request");
    }
    // counters come from a facade run of the same workload (the recorded
    // engine above doesn't expose the registry): probe ops scale with the
    // probed requests' blocks, and the shard-lock tripwire stays zero
    let counter = |server: &Server, name: &str| {
        server
            .counters()
            .iter()
            .find(|(k, _)| *k == name)
            .map(|&(_, v)| v)
            .unwrap_or_else(|| panic!("counter {name} missing"))
    };
    let mut per_workers = Vec::new();
    for workers in [1usize, 2, 4, 8] {
        let server = sim_server(cfg_with(PlacementKind::ContextAware, 4, workers), &corpus);
        for (i, j) in turn_waves(&w.requests) {
            server.serve_batch(&w.requests[i..j]).expect("serve wave");
        }
        let ops = counter(&server, "placement_probe_ops");
        assert!(ops > 0, "context-aware serving must probe");
        assert_eq!(
            counter(&server, "placement_probe_shard_locks"),
            0,
            "probe path took a shard lock"
        );
        per_workers.push((ops, counter(&server, "placement_probes")));
    }
    for pair in &per_workers[1..] {
        assert_eq!(*pair, per_workers[0], "probe counters vary with workers");
    }
}

#[test]
fn context_aware_strictly_beats_session_hash_on_recurring_contexts() {
    // the Table 6 / §7.2 acceptance pin: many users sharing a few RAG
    // corpora. Blind hashing scatters each corpus group over the shards
    // and every shard re-prefills it; context-aware placement keeps each
    // group on one shard and shares the prefix.
    let corpus = Arc::new(corpus_for(Dataset::MtRag));
    let w = recurring(Dataset::MtRag, 24, 2, 4, 6, 0x70C);
    let run = |placement: PlacementKind| {
        let server = sim_server(cfg_with(placement, 4, 2), &corpus);
        for (i, j) in turn_waves(&w.requests) {
            server.serve_batch(&w.requests[i..j]).expect("serve wave");
        }
        let (m, _) = server.metrics().expect("metrics");
        (m.total_cached_tokens, m.total_affinity_hit_tokens)
    };
    let (aware_cached, aware_affinity) = run(PlacementKind::ContextAware);
    let (hashed_cached, hashed_affinity) = run(PlacementKind::SessionHash);
    assert!(
        aware_cached > hashed_cached,
        "context-aware {aware_cached} <= session-hash {hashed_cached} cached tokens"
    );
    assert!(
        aware_affinity > 0,
        "context-aware reuse must be attributed to affinity placements"
    );
    assert_eq!(hashed_affinity, 0, "session hash can never claim affinity");
}
