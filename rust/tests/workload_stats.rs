//! Integration: workload statistics match the paper's measured traces
//! (Fig. 11 coverage ordering, §3.1 overlap rates).

use contextpilot::workload::access::AccessStats;
use contextpilot::workload::*;
use std::collections::HashSet;

#[test]
fn fig11_coverage_close_to_paper_targets() {
    for (dataset, target, tol) in [
        (Dataset::MultihopRag, 0.792, 0.25),
        (Dataset::NarrativeQa, 0.574, 0.25),
        (Dataset::Qasper, 0.496, 0.25),
    ] {
        let p = DatasetProfile::get(dataset);
        let w = multi_session(dataset, 800, p.k, 0xF11);
        let cov = AccessStats::from_workload(&w).top_coverage(0.2);
        assert!(
            (cov - target).abs() < tol,
            "{}: coverage {cov} vs paper {target}",
            dataset.name()
        );
    }
}

#[test]
fn mtrag_cross_turn_overlap_near_forty_percent() {
    // §3.1: ~40% of retrieved documents in any turn overlap earlier turns
    let mut overlaps = 0usize;
    let mut total = 0usize;
    for seed in 0..20u64 {
        let w = multi_turn(Dataset::MtRag, 10, 10, seed);
        let mut seen: HashSet<_> = HashSet::new();
        for r in &w.requests {
            if r.turn > 0 {
                total += r.context.len();
                overlaps += r.context.iter().filter(|b| seen.contains(*b)).count();
            }
            seen.extend(r.context.iter().copied());
        }
    }
    let rate = overlaps as f64 / total as f64;
    assert!((0.30..0.60).contains(&rate), "overlap rate {rate}");
}

#[test]
fn openclaw_doc_analysis_is_prefill_heavy() {
    let (w, decode) = openclaw(10, 20, 1, false);
    // average decode well under typical prompt length
    let mean_decode: f64 = decode.iter().sum::<usize>() as f64 / decode.len() as f64;
    assert!(mean_decode < 200.0);
    // heavy cross-turn block reuse within a task
    let mut reuse = 0usize;
    let mut total = 0usize;
    for s in 0..10u32 {
        let task: Vec<_> = w
            .requests
            .iter()
            .filter(|r| r.session == contextpilot::types::SessionId(s))
            .collect();
        let mut seen: HashSet<_> = HashSet::new();
        for r in task {
            if r.turn > 0 {
                total += r.context.len();
                reuse += r.context.iter().filter(|b| seen.contains(*b)).count();
            }
            seen.extend(r.context.iter().copied());
        }
    }
    assert!(
        reuse as f64 / total as f64 > 0.6,
        "agent re-reads should dominate: {}",
        reuse as f64 / total as f64
    );
}

#[test]
fn workloads_deterministic_across_calls() {
    for seed in [1u64, 99] {
        let a = hybrid(Dataset::MtRag, 4, 4, 8, seed);
        let b = hybrid(Dataset::MtRag, 4, 4, 8, seed);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.session, y.session);
        }
    }
}
