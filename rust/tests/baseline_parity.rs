//! Integration: the baseline re-implementations behave per their papers'
//! mechanisms — the qualitative contracts §2.3 relies on.

use contextpilot::engine::ModelSku;
use contextpilot::experiments::{corpus_for, run_f1, run_system, RunConfig, SystemKind};
use contextpilot::workload::{multi_session, Dataset};

fn setup() -> (
    contextpilot::workload::Workload,
    contextpilot::corpus::Corpus,
    RunConfig,
) {
    let dataset = Dataset::MultihopRag;
    let corpus = corpus_for(dataset);
    let w = multi_session(dataset, 100, 15, 0xBA5E);
    let cfg = RunConfig::for_dataset(ModelSku::Qwen3_32B, dataset);
    (w, corpus, cfg)
}

#[test]
fn exact_prefix_baselines_have_low_hit_ratio() {
    // §2.3: despite substantial overlap, exact matching hits rarely
    let (w, corpus, cfg) = setup();
    let radix = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
    let lm = run_system(&SystemKind::LMCache, &w, &corpus, &cfg);
    assert!(radix.hit_ratio() < 0.25, "radix hit {}", radix.hit_ratio());
    assert!(lm.hit_ratio() <= radix.hit_ratio() + 0.02, "doc-granular cannot beat token-granular");
}

#[test]
fn exact_baselines_preserve_accuracy() {
    let (w, corpus, cfg) = setup();
    let radix = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
    let lm = run_system(&SystemKind::LMCache, &w, &corpus, &cfg);
    // identical prompts, identical quality (the paper's equal F1 columns)
    assert!((radix.mean_quality() - lm.mean_quality()).abs() < 1e-9);
}

#[test]
fn cacheblend_trades_accuracy_for_reuse() {
    let (w, corpus, cfg) = setup();
    let blend = run_system(&SystemKind::CacheBlend, &w, &corpus, &cfg);
    let radix = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
    assert!(
        blend.hit_ratio() > radix.hit_ratio() * 1.5,
        "blend reuse {} vs radix {}",
        blend.hit_ratio(),
        radix.hit_ratio()
    );
    let f_blend = run_f1(&blend, &w, &cfg, 60.4);
    let f_radix = run_f1(&radix, &w, &cfg, 60.4);
    // §2.3: approximate matching costs ~9-11 F1 points
    assert!(
        f_radix - f_blend > 4.0,
        "blend {f_blend} vs radix {f_radix}"
    );
}

#[test]
fn lmcache_offload_penalty_slows_reused_tokens() {
    let (w, corpus, cfg) = setup();
    let mut lm = run_system(&SystemKind::LMCache, &w, &corpus, &cfg);
    let mut radix = run_system(&SystemKind::RadixCache, &w, &corpus, &cfg);
    // same matching family, but LMCache pays offload: TTFT >= radix
    assert!(lm.mean_ttft() >= radix.mean_ttft() - 1e-9);
}
